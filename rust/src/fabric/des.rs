//! Flow-level discrete-event simulation with max-min fair sharing and the
//! Slingshot congestion-management behaviour of paper §3.1.
//!
//! Rates are the exact max-min fair allocation (progressive filling with
//! per-flow issue-rate caps); events are flow arrivals and completions.
//! Congestion management models the paper's description literally:
//!
//! > "The switch hardware will detect congestion, identify its causes, and
//! >  determine whether traffic flowing through a congested point is
//! >  contributing to the congestion or is a victim of it. ... stiff back
//! >  pressure to congesting traffic ... All traffic not contributing to
//! >  the congestion is unaffected."
//!
//! With `congestion_mgmt = true`, incast members are rate-limited to their
//! fair share at the *root* of the incast (which exact max-min provides)
//! and victims sharing intermediate links are unaffected. With
//! `congestion_mgmt = false` (the GPCNet "congested" baseline), queues at
//! the incast root back up into the fabric: every flow crossing a link
//! contaminated by incast traffic is penalized.

//! Two solvers share the model above:
//!
//! * [`DesSim::run`] — the **incremental** solver: per-flow rates are held
//!   between events and, at each arrival/completion, only the affected
//!   *component* — flows transitively sharing links with the changed flow —
//!   is re-solved. Components are link-disjoint, so the max-min allocation
//!   of every other component is unchanged by construction; completion
//!   times are projected and kept in an event heap. The component solve is
//!   progressive filling over a per-link flow index with a lazy min-heap of
//!   link fair-share levels (levels are monotone non-decreasing during
//!   filling, so stale heap entries are safely re-inserted).
//! * [`DesSim::run_oracle`] — the original dense full recompute: exact
//!   max-min by whole-system progressive filling at every event. Kept as
//!   the equivalence oracle for `tests/des_equivalence.rs` and the
//!   baseline for `benches/fabric.rs` (see EXPERIMENTS.md §Perf).
//!
//! Both compute the same unique max-min fixpoint, so per-flow finish times
//! agree to floating-point noise (the equivalence suite asserts 1e-9
//! relative).
//!
//! **Component-parallel batches** (EXPERIMENTS.md §Parallel solve): the
//! per-event component walk now *partitions* the affected flows into
//! link-disjoint components instead of lumping them into one set. Each
//! component's solve — entry queueing, exact max-min, congestion
//! classification — is a pure function of the synced pre-batch state,
//! so when a batch spans several components (multi-group halos, multi-
//! tenant mixes) they are fanned out over
//! [`crate::campaign::pool::par_map_pooled`] worker scratches
//! ([`DesOpts::solver_threads`] > 1); the merge and the commit
//! (rate/heap/counter writes) stay serial in component-id order, so
//! results are bit-identical at every thread count.

use super::workload::{DagKind, DagWorkload, RoundSource, StreamNode, NO_KEY};
use super::{FlowTimes, RoutedFlow};
use super::faults::{FaultPolicy, FaultSchedule};
use crate::topology::{LinkId, Path, Topology};
use rustc_hash::{FxHashMap, FxHashSet};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};

/// DES knobs.
#[derive(Debug, Clone)]
pub struct DesOpts {
    /// Slingshot congestion management on (paper default) or off.
    pub congestion_mgmt: bool,
    /// Ejection links with at least this many concurrent flows form an
    /// incast.
    pub incast_threshold: usize,
    /// Rate multiplier applied to victims when congestion mgmt is OFF.
    pub victim_penalty: f64,
    /// Degraded links (§3.4 lane-disable): bandwidth multiplier per
    /// link. A `BTreeMap` on purpose (detlint R1): capacity
    /// installation iterates this map, and iteration order must be a
    /// pure function of the contents.
    pub degraded: BTreeMap<LinkId, f64>,
    /// Switch per-port queue capacity: bounds how much in-flight bulk data
    /// can sit ahead of a message on each hop (drives the GPCNet latency
    /// inflation of Fig 5).
    pub queue_cap_bytes: f64,
    /// Worker threads for the component-parallel batch solve (1 =
    /// fully serial). Results are bit-identical at every value: the
    /// per-component solve is a pure function of the synced pre-batch
    /// state and the merge/commit is serial in component-id order —
    /// the knob only changes wall time (EXPERIMENTS.md §Parallel solve).
    pub solver_threads: usize,
    /// Service components whose flows all share one saturated link (the
    /// NIC-bound equal-share common case) with an O(flows) closed-form
    /// update instead of the full max-min waterfill. Bit-identical to
    /// the general path — the detection rule only fires where the
    /// waterfill's first fixing step provably covers the whole component
    /// (EXPERIMENTS.md §Raw speed) — so this is purely a wall-time knob,
    /// kept togglable for the equivalence suite and the bench baseline.
    pub single_bottleneck_fastpath: bool,
    /// Mid-run fault timeline ([`super::faults`]): time-ordered capacity
    /// events executed inside the event heap (`EV_FAULT`), with the
    /// schedule's [`super::FaultPolicy`] applied to in-flight flows
    /// crossing a link that goes down. `None` (and an empty schedule)
    /// is the healthy fabric — the hook costs nothing when unused
    /// (`fault_overhead` bench gate). A schedule firing everything at
    /// `t = 0` is bit-identical to the same multipliers installed
    /// statically via [`DesOpts::degraded`]
    /// (`tests/des_equivalence.rs`).
    pub faults: Option<super::faults::FaultSchedule>,
    /// Overload-control policy for the open-loop service tier
    /// ([`super::degrade`]): per-class admission shedding, deadlines
    /// (`EV_DEADLINE`), shared retry budgets and hedged requests
    /// (`EV_HEDGE`), enforced by the *streaming* executor and the
    /// `OpenLoopSource` adapter. `None` — and an inert policy
    /// ([`super::degrade::ServicePolicy::is_inert`]) — is bit-identical
    /// to the policy-free path: no events are scheduled and nothing is
    /// shed (`degrade_overhead` bench gate). The batch executors
    /// (`solve` / `dag`) honor only the retry-budget control (their
    /// flows are all class 0); deadlines and hedging are
    /// streaming-tier semantics.
    pub policies: Option<super::degrade::ServicePolicy>,
}

impl Default for DesOpts {
    fn default() -> Self {
        Self {
            congestion_mgmt: true,
            incast_threshold: 4,
            victim_penalty: 0.30,
            degraded: BTreeMap::new(),
            queue_cap_bytes: 256.0 * 1024.0,
            solver_threads: 1,
            single_bottleneck_fastpath: true,
            faults: None,
            policies: None,
        }
    }
}

/// Below this many flows in an event batch the fork-join fan-out costs
/// more than the solve itself; such batches run inline regardless of
/// [`DesOpts::solver_threads`]. Purely a wall-time knob — per-component
/// arithmetic is identical on either path.
const PAR_SOLVE_MIN_FLOWS: usize = 128;

/// A flow with an arrival time.
#[derive(Debug, Clone)]
pub struct TimedFlow {
    pub rf: RoutedFlow,
    pub start: f64,
}

#[derive(Debug, Clone)]
pub struct DesResult {
    /// Absolute completion time per flow (same order as input).
    pub finish: Vec<f64>,
    pub makespan: f64,
    /// Flows that crossed a congested point as contributors.
    pub contributors: usize,
    /// Flows penalized as victims (only when congestion mgmt is off).
    pub victims: usize,
    /// Event batches that re-solved at least one component.
    pub solve_batches: usize,
    /// Link-disjoint components re-solved across all batches;
    /// `components_solved / solve_batches` is the mean component
    /// parallelism the batch fan-out can exploit (the
    /// `parallel_components_per_batch` bench ratio).
    pub components_solved: usize,
    /// Of `components_solved`, how many were serviced by the
    /// single-bottleneck fast path (see
    /// [`DesOpts::single_bottleneck_fastpath`]). Diagnostic only —
    /// rates are bit-identical either way.
    pub fastpath_components: usize,
    /// Flows failed by the fault policy (exhausted retries, no viable
    /// reroute, or [`super::FaultPolicy::Abort`]); their `finish` entry
    /// is `NaN` and they are excluded from `makespan`.
    pub failed_flows: usize,
}

/// Result of executing a [`DagWorkload`] (closed-loop simulation).
#[derive(Debug, Clone)]
pub struct DagResult {
    /// Absolute completion time per DAG node (same order as the
    /// workload's nodes). For transfers this includes the zero-load
    /// latency and entry queueing delay — the time the *receiver* sees
    /// the data and dependents are released.
    pub node_finish: Vec<f64>,
    pub makespan: f64,
    /// Flows that crossed a congested point as contributors.
    pub contributors: usize,
    /// Flows penalized as victims (only when congestion mgmt is off).
    pub victims: usize,
    /// Event batches that re-solved at least one component.
    pub solve_batches: usize,
    /// Link-disjoint components re-solved across all batches (see
    /// [`DesResult::components_solved`]).
    pub components_solved: usize,
    /// Components serviced by the single-bottleneck fast path (see
    /// [`DesResult::fastpath_components`]).
    pub fastpath_components: usize,
    /// Flows failed by the fault policy; their DAG nodes (and every
    /// transitive dependent) never complete.
    pub failed_flows: usize,
    /// Nodes that never completed because a failed flow's dependents
    /// were never released; their `node_finish` entry is `NaN` and they
    /// are excluded from `makespan`.
    pub aborted_nodes: usize,
}

/// Result of a streaming ([`DesSim::run_stream`]) closed-loop run.
#[derive(Debug, Clone)]
pub struct StreamResult {
    /// Last node completion (includes latency/queue tails).
    pub makespan: f64,
    /// Non-empty rounds pulled from the source.
    pub rounds: usize,
    /// Total DAG nodes materialized over the whole run.
    pub total_nodes: usize,
    /// Peak simultaneously live (materialized, unretired) nodes — the
    /// memory high-water mark the windowed executor bounds; `<<`
    /// `total_nodes` whenever the workload's dependency skew is small
    /// relative to its round count.
    pub peak_live_nodes: usize,
    /// Flows that crossed a congested point as contributors.
    pub contributors: usize,
    /// Flows penalized as victims (only when congestion mgmt is off).
    pub victims: usize,
    /// Nodes whose dependencies had all finished before the node was
    /// materialized (release clamped to the simulation clock). Zero
    /// means the streamed execution is equivalent to running the fully
    /// materialized DAG (given the uniform-buffer precondition
    /// documented on [`DesSim::run_stream`]).
    pub late_releases: usize,
    /// Event batches that re-solved at least one component.
    pub solve_batches: usize,
    /// Link-disjoint components re-solved across all batches (see
    /// [`DesResult::components_solved`]).
    pub components_solved: usize,
    /// Components serviced by the single-bottleneck fast path (see
    /// [`DesResult::fastpath_components`]).
    pub fastpath_components: usize,
    /// Flows failed by the fault policy (see
    /// [`DagResult::failed_flows`]).
    pub failed_flows: usize,
    /// Of the nodes *materialized*, how many never completed (failed
    /// flows and their never-released dependents). Rounds the source
    /// never materialized because of the stall are not counted.
    /// Deadline-abandoned nodes are *not* included — they terminate
    /// (and retire) at their abandon instant and are counted in
    /// [`StreamResult::abandoned_flows`].
    pub aborted_nodes: usize,
    /// Requests abandoned by a [`DesOpts::policies`] deadline
    /// (`EV_DEADLINE`): their in-flight flows detached, bandwidth
    /// returned to survivors, node terminated at the deadline instant.
    pub abandoned_flows: usize,
    /// Requests duplicated onto a disjoint minimal route by a
    /// [`DesOpts::policies`] hedge (`EV_HEDGE`). First completion wins;
    /// the loser is cancelled and its slot recycled.
    pub hedged_flows: usize,
}

/// What the streaming executor's outcome sink
/// ([`DesSession::stream_outcomes`]) reports for a node. `Finished` is
/// terminal-success (the plain `stream_sink` callback); `Failed` and
/// `Abandoned` are terminal-failure (the node never completes);
/// `Hedged` is a non-terminal notification that a hedge twin was
/// spawned for the node's request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlowOutcome {
    /// The node completed at the reported time.
    Finished,
    /// The fault policy failed the request for good (exhausted
    /// retries/budget, no viable reroute, or `Abort`).
    Failed,
    /// A [`DesOpts::policies`] deadline abandoned the request.
    Abandoned,
    /// A hedge twin was spawned (informational; a terminal outcome for
    /// the same node follows later).
    Hedged,
}

pub struct DesSim<'t> {
    topo: &'t Topology,
    opts: DesOpts,
}

/// One live (materialized, unretired) node of the streaming executor.
struct StreamLive {
    kind: StreamKind,
    deps_left: u32,
    /// Global ids of already-materialized dependents.
    succs: Vec<u32>,
    done: bool,
    finish: f64,
    /// Release floor accumulated so far: max finish among dependencies
    /// that were already complete when observed.
    release: f64,
    round: u32,
}

enum StreamKind {
    Compute(f64),
    /// Dense flow slot currently bound to this node.
    Xfer(u32),
}

/// One key's frontier in the streaming executor: the nodes of the last
/// round that touched the key. Once that round is fully complete the
/// entry is *collapsed* — the live node ids are replaced by the max
/// finish time (`done_floor`), which is all a future dependent can
/// extract from finished nodes — so the round stops being
/// frontier-pinned and retires even if the key is never touched again
/// (per-node refcount retirement; previously a once-touched key kept
/// its round, and every later round, live forever).
#[derive(Debug, Default)]
struct FrontierEntry {
    /// Round the live ids belong to; `u32::MAX` once collapsed.
    round: u32,
    ids: Vec<u32>,
    /// Max finish among this key's already-retired dependency nodes.
    done_floor: f64,
}

/// Reusable solver arena shared by every DES executor: the interned
/// dense link/flow representation ([`Dense`]), the mutable solve state
/// ([`SolveState`]), the event heap, the per-event work lists and the
/// streaming window. `DesSim::run`, `run_dag` and `run_stream` allocate
/// one internally per call; the `*_with` variants borrow a caller-owned
/// scratch and only *reset* it (keeping every allocation), so
/// repeated-structure drivers — `World` supersteps pricing thousands of
/// per-step DAGs, campaign workers sweeping scenarios — stop churning
/// the allocator. A reset scratch is observationally identical to a
/// fresh one (results never depend on scratch history; asserted by
/// `tests/des_equivalence.rs`).
#[derive(Default)]
pub struct DesScratch {
    d: Dense,
    map: LinkMap,
    st: SolveState,
    cscratch: CompScratch,
    /// Per-worker scratches of the fanned batch solve
    /// ([`crate::campaign::pool::par_map_pooled`]): warmed once, reused
    /// across every fanned batch of every run on this scratch.
    par_cscratch: Vec<CompScratch>,
    /// Persistent worker pool for the fanned batch solve: spawned lazily
    /// on the first fan-out, then reused (parked between batches) for
    /// every later batch of every run on this scratch — thousands of
    /// batches per run would otherwise pay a `thread::spawn` each.
    /// Threads, not an arena: excluded from [`Self::capacity_signature`]
    /// and untouched by reset.
    par_pool: Option<crate::campaign::pool::WorkerPool>,
    heap: BinaryHeap<Reverse<Ev>>,
    completions: Vec<usize>,
    arrivals: Vec<usize>,
    // ---- run_dag bookkeeping ----
    succs: Vec<Vec<u32>>,
    deps_left: Vec<u32>,
    node_done: Vec<bool>,
    /// Flow slot -> node id (`run_dag` and streaming).
    flow_node: Vec<u32>,
    /// Node id -> flow slot (`run_dag`; `u32::MAX` for compute nodes).
    node_flow: Vec<u32>,
    // ---- streaming executor window ----
    nodes: VecDeque<StreamLive>,
    round_pending: VecDeque<u32>,
    round_frontier_refs: VecDeque<u32>,
    round_keys: VecDeque<Vec<u32>>,
    frontier: FxHashMap<u32, FrontierEntry>,
    flow_rf: Vec<RoutedFlow>,
    free_slots: Vec<u32>,
    /// Flow slot -> service class (streaming; the degradation layer's
    /// per-class policy lookup, [`RoundSource::node_class`]).
    flow_class: Vec<u8>,
    /// Flow slot -> hedge twin slot (`u32::MAX` = none): the pairing
    /// first-completion-wins cancellation resolves through.
    hedge_mate: Vec<u32>,
}

impl DesScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Event batches of the last run whose per-component solves were
    /// fanned out over worker threads (0 when `solver_threads <= 1` or
    /// no batch crossed the fan-out threshold). Diagnostic only —
    /// results never depend on it.
    pub fn fanned_batches(&self) -> usize {
        self.st.fanned
    }

    /// Sum of the heap-allocated capacities of every arena in this
    /// scratch. Two runs of the same workload through one scratch must
    /// leave this unchanged — the reset-not-reallocate contract campaign
    /// workers rely on (asserted by `tests/des_equivalence.rs`).
    pub fn capacity_signature(&self) -> usize {
        let d = &self.d;
        let st = &self.st;
        let cs = &self.cscratch;
        d.link_ids.capacity()
            + d.link_uids.capacity()
            + d.cap.capacity()
            + d.nic_min.capacity()
            + d.flow_links.capacity()
            + d.flow_len.capacity()
            + d.flow_cap.capacity()
            + d.flow_last.capacity()
            + self.map.ids.capacity()
            + st.remaining.capacity()
            + st.rate.capacity()
            + st.last_sync.capacity()
            + st.queue_penalty.capacity()
            + st.active.capacity()
            + st.done.capacity()
            + st.epoch.capacity()
            + st.retry.capacity()
            + st.link_flows.capacity()
            + st.link_flows.iter().map(Vec::capacity).sum::<usize>()
            + st.eject_count.capacity()
            + st.link_seen.capacity()
            + st.flow_seen.capacity()
            + st.comp.capacity()
            + st.comp_ends.capacity()
            + st.lstack.capacity()
            + st.contributors.capacity()
            + st.victims.capacity()
            + cs.rem_cap.capacity()
            + cs.count.capacity()
            + cs.slot.capacity()
            + cs.touched.capacity()
            + cs.inflight.capacity()
            + cs.contaminated.capacity()
            + self.par_cscratch.capacity()
            + self
                .par_cscratch
                .iter()
                .map(|w| {
                    w.rem_cap.capacity()
                        + w.count.capacity()
                        + w.slot.capacity()
                        + w.touched.capacity()
                        + w.inflight.capacity()
                        + w.contaminated.capacity()
                })
                .sum::<usize>()
            + self.heap.capacity()
            + self.completions.capacity()
            + self.arrivals.capacity()
            + self.succs.capacity()
            + self.succs.iter().map(Vec::capacity).sum::<usize>()
            + self.deps_left.capacity()
            + self.node_done.capacity()
            + self.flow_node.capacity()
            + self.node_flow.capacity()
            + self.nodes.capacity()
            + self.round_pending.capacity()
            + self.round_frontier_refs.capacity()
            + self.round_keys.capacity()
            + self.round_keys.iter().map(Vec::capacity).sum::<usize>()
            + self.frontier.capacity()
            + self.flow_rf.capacity()
            + self.free_slots.capacity()
            + self.flow_class.capacity()
            + self.hedge_mate.capacity()
    }

    /// Clear every run-local structure while retaining allocations.
    fn reset(&mut self) {
        // un-mint the previous run's link ids before the dense store
        // forgets which universe slots it used
        for &u in &self.d.link_uids {
            self.map.ids[u as usize] = u32::MAX;
        }
        self.d.reset();
        self.st.reset();
        self.heap.clear();
        self.completions.clear();
        self.arrivals.clear();
        for v in &mut self.succs {
            v.clear();
        }
        self.deps_left.clear();
        self.node_done.clear();
        self.flow_node.clear();
        self.node_flow.clear();
        self.nodes.clear();
        self.round_pending.clear();
        self.round_frontier_refs.clear();
        self.round_keys.clear();
        self.frontier.clear();
        self.flow_rf.clear();
        self.free_slots.clear();
        self.flow_class.clear();
        self.hedge_mate.clear();
    }
}

/// Windowed node/flow store of [`DesSim::run_stream`]: nodes are created
/// in round order, held in a deque addressed by `id - base`, and retired
/// in round order once a prefix round is fully complete and no key's
/// frontier holds live references to it (fully-done frontier entries
/// collapse to their max finish, see [`FrontierEntry`]). Flow slots
/// (dense link lists + solver state) recycle independently through
/// `free_slots`. All bulk storage lives in the borrowed [`DesScratch`].
struct StreamExec<'a, 't> {
    sim: &'a DesSim<'t>,
    s: &'a mut DesScratch,
    /// Global id of `s.nodes[0]`.
    base: u32,
    round_base: u32,
    materialized_rounds: u32,
    exhausted: bool,
    nodes_done: usize,
    total_nodes: usize,
    peak_live: usize,
    late_releases: usize,
    rounds: usize,
    /// An [`EV_ROUND`] wake-up is already queued (at most one in flight):
    /// the source declared, via
    /// [`RoundSource::next_round_not_before`], that its next round must
    /// not materialize before that time. While set, the main loop keeps
    /// running even if every materialized node is done — more rounds are
    /// still coming.
    round_ev_pending: bool,
}

impl StreamExec<'_, '_> {
    fn node(&self, id: u32) -> &StreamLive {
        &self.s.nodes[(id - self.base) as usize]
    }

    fn node_mut(&mut self, id: u32) -> &mut StreamLive {
        &mut self.s.nodes[(id - self.base) as usize]
    }

    /// Pull and wire one more (non-empty) round from the source.
    /// Dependency-free nodes — releasable at their floors — are pushed
    /// onto `pending` for the caller to schedule. Returns false once the
    /// source is exhausted.
    fn materialize_next_round(
        &mut self,
        src: &mut dyn RoundSource,
        pending: &mut Vec<u32>,
    ) -> bool {
        let round = loop {
            match src.next_round() {
                None => {
                    self.exhausted = true;
                    return false;
                }
                Some(r) if r.is_empty() => continue, // empty rounds: no-ops
                Some(r) => break r,
            }
        };
        let k = self.materialized_rounds;
        // verify the round's structural contracts (sentinel use, routed
        // paths, finite floors) before any of it is wired into the
        // frontier — a malformed round must fail here, not deadlock later
        #[cfg(debug_assertions)]
        super::analysis::debug_check_round(&round, k);
        self.materialized_rounds += 1;
        self.rounds += 1;
        self.s.round_pending.push_back(round.len() as u32);
        self.s.round_frontier_refs.push_back(0);
        self.s.round_keys.push_back(Vec::new());
        // within the round, everyone sees the pre-round frontier; the
        // staged (key, id) pairs commit afterwards (DagBuilder::end_round)
        let mut staged: Vec<(u32, u32)> = Vec::with_capacity(2 * round.len());
        for (ni, n) in round.into_iter().enumerate() {
            let id = self.base + self.s.nodes.len() as u32;
            let (a, b, start, kind) = match n {
                StreamNode::Compute { a, b, dt, start } => {
                    (a, b, start, StreamKind::Compute(dt.max(0.0)))
                }
                StreamNode::Xfer { a, b, rf, start } => {
                    let bytes = rf.flow.bytes as f64;
                    let slot = if let Some(fs) = self.s.free_slots.pop() {
                        let fs = fs as usize;
                        self.sim.push_flow(
                            &mut self.s.d, &mut self.s.map, &rf, Some(fs),
                        );
                        self.s.st.recycle_flow(fs, bytes);
                        self.s.flow_node[fs] = id;
                        self.s.flow_rf[fs] = rf;
                        self.s.flow_class[fs] = 0;
                        self.s.hedge_mate[fs] = u32::MAX;
                        fs
                    } else {
                        let fs = self.sim.push_flow(
                            &mut self.s.d, &mut self.s.map, &rf, None,
                        );
                        self.s.st.push_flow(bytes);
                        self.s.flow_node.push(id);
                        self.s.flow_rf.push(rf);
                        self.s.flow_class.push(0);
                        self.s.hedge_mate.push(u32::MAX);
                        fs
                    };
                    // degradation layer ([`DesOpts::policies`]): tag the
                    // slot with its service class and arm the per-request
                    // deadline / hedge timers off the node's arrival
                    // floor. Both events validate against the node id at
                    // fire time, so slot recycling cannot mis-deliver
                    // them. Off (infinite) knobs schedule nothing — the
                    // inert-policy path stays bit-identical to no policy.
                    if let Some(pol) = self.sim.opts.policies.as_ref() {
                        let class = src.node_class(ni);
                        self.s.flow_class[slot] = class;
                        let cp = pol.class(class);
                        let floor = start.max(0.0);
                        if cp.deadline.is_finite() {
                            self.s.heap.push(Reverse(Ev {
                                t: floor + cp.deadline,
                                kind: EV_DEADLINE,
                                flow: slot as u32,
                                epoch: id,
                            }));
                        }
                        if cp.hedge_delay.is_finite() {
                            self.s.heap.push(Reverse(Ev {
                                t: floor + cp.hedge_delay,
                                kind: EV_HEDGE,
                                flow: slot as u32,
                                epoch: id,
                            }));
                        }
                    }
                    (a, b, start, StreamKind::Xfer(slot as u32))
                }
            };
            let mut ln = StreamLive {
                kind,
                deps_left: 0,
                succs: Vec::new(),
                done: false,
                finish: f64::NAN,
                // the node's release floor: its absolute start floor,
                // raised by finished dependencies below / on release
                release: start.max(0.0),
                round: k,
            };
            // NO_KEY nodes ride outside the frontier: no dependencies
            // taken, none offered — released purely by their floor
            // (open-loop arrivals; see `workload::NO_KEY`).
            if a != NO_KEY {
                if let Some(e) = self.s.frontier.get(&a) {
                    ln.release = ln.release.max(e.done_floor);
                    for &dep in &e.ids {
                        let dn = &mut self.s.nodes[(dep - self.base) as usize];
                        if dn.done {
                            ln.release = ln.release.max(dn.finish);
                        } else {
                            dn.succs.push(id);
                            ln.deps_left += 1;
                        }
                    }
                }
                staged.push((a, id));
            }
            if b != a && b != NO_KEY {
                staged.push((b, id));
            }
            if ln.deps_left == 0 {
                pending.push(id);
            }
            self.s.nodes.push_back(ln);
            self.total_nodes += 1;
        }
        // commit frontiers: every key touched this round replaces its
        // entry with this round's nodes
        let mut fresh: FxHashMap<u32, Vec<u32>> = FxHashMap::default();
        for &(key, id) in &staged {
            fresh.entry(key).or_default().push(id);
        }
        for (key, ids) in fresh {
            if let Some(e) = self.s.frontier.get(&key) {
                if e.round != u32::MAX {
                    self.s.round_frontier_refs
                        [(e.round - self.round_base) as usize] -= 1;
                }
            }
            self.s.round_frontier_refs[(k - self.round_base) as usize] += 1;
            self.s.round_keys[(k - self.round_base) as usize].push(key);
            self.s.frontier.insert(
                key,
                FrontierEntry { round: k, ids, done_floor: 0.0 },
            );
        }
        self.peak_live = self.peak_live.max(self.s.nodes.len());
        self.s.st.grow_links(self.s.d.cap.len());
        true
    }

    /// Materialize rounds until `upto` rounds exist (or the source ends).
    /// Honors [`RoundSource::next_round_not_before`]: if the source's
    /// next round may not materialize before some future time `t > now`,
    /// stops and returns `Some(t)` so the caller can schedule an
    /// [`EV_ROUND`] wake-up instead of pulling the round early (the
    /// bounded-memory contract of the open-loop tier). Closed-loop
    /// sources keep the default `0.0` floor and are never deferred.
    fn ensure_rounds(
        &mut self,
        src: &mut dyn RoundSource,
        upto: u32,
        now: f64,
        pending: &mut Vec<u32>,
    ) -> Option<f64> {
        while !self.exhausted && self.materialized_rounds < upto {
            let not_before = src.next_round_not_before();
            if not_before > now {
                return Some(not_before);
            }
            if !self.materialize_next_round(src, pending) {
                break;
            }
        }
        None
    }

    /// Mark node `id` complete; returns its dependents for release
    /// propagation (the successor list is consumed — no new successors
    /// can attach once every frontier referencing the node is replaced
    /// or collapsed, and until then the node stays live for wiring-time
    /// finish reads).
    fn finish_node(&mut self, id: u32, now: f64) -> Vec<u32> {
        let base = self.base;
        let round_base = self.round_base;
        let n = &mut self.s.nodes[(id - base) as usize];
        debug_assert!(!n.done, "node {id} finished twice");
        n.done = true;
        n.finish = now;
        let round = n.round;
        let succs = std::mem::take(&mut n.succs);
        self.nodes_done += 1;
        self.s.round_pending[(round - round_base) as usize] -= 1;
        succs
    }

    /// Retire fully finished prefix rounds: their nodes leave the
    /// window. Frontier entries still pointing at a fully finished round
    /// collapse to their max finish first ([`FrontierEntry`]), so a key
    /// touched once and never again cannot pin the round — or any later
    /// round — live.
    fn retire(&mut self) {
        loop {
            let pend = match self.s.round_pending.front() {
                Some(&p) => p,
                None => break,
            };
            if pend != 0 {
                break;
            }
            if self.s.round_frontier_refs[0] != 0 {
                let keys = std::mem::take(&mut self.s.round_keys[0]);
                for &key in &keys {
                    let stale = match self.s.frontier.get(&key) {
                        Some(e) => e.round == self.round_base,
                        None => false,
                    };
                    if !stale {
                        continue; // key re-touched later: not ours
                    }
                    let e = self.s.frontier.get_mut(&key).expect("entry");
                    let ids = std::mem::take(&mut e.ids);
                    let mut floor = e.done_floor;
                    for &id in &ids {
                        let dn = &self.s.nodes[(id - self.base) as usize];
                        debug_assert!(dn.done);
                        floor = floor.max(dn.finish);
                    }
                    let e = self.s.frontier.get_mut(&key).expect("entry");
                    e.done_floor = floor;
                    e.round = u32::MAX;
                    self.s.round_frontier_refs[0] -= 1;
                }
                debug_assert_eq!(self.s.round_frontier_refs[0], 0);
            }
            while let Some(front) = self.s.nodes.front() {
                if front.round != self.round_base {
                    break;
                }
                debug_assert!(front.done);
                self.s.nodes.pop_front();
                self.base += 1;
            }
            self.s.round_pending.pop_front();
            self.s.round_frontier_refs.pop_front();
            self.s.round_keys.pop_front();
            self.round_base += 1;
        }
    }
}

/// Longest link list a routed path can produce: NIC injection +
/// (local, global, local, global, local) Valiant fabric hops + NIC
/// ejection. The dense flow store uses it as a fixed stride.
const MAX_PATH_LINKS: usize = 8;

/// Universe-indexed link-id mint: maps [`Topology::link_index`] slots to
/// run-local interned ids (`u32::MAX` = not yet minted). A flat array
/// instead of the old per-run `FxHashMap<LinkId, u32>` — interning a
/// link is one load, full-Aurora's ~1.08M-slot universe is ~4.1 MiB
/// allocated once per scratch, and `DesScratch::reset` un-mints only the
/// slots the previous run touched (via `Dense::link_uids`).
#[derive(Default)]
struct LinkMap {
    ids: Vec<u32>,
}

impl LinkMap {
    /// Grow to `universe` slots (all unmapped). Called once per run;
    /// never shrinks, so scratch reuse across topologies stays safe.
    fn ensure(&mut self, universe: usize) {
        if self.ids.len() < universe {
            self.ids.resize(universe, u32::MAX);
        }
    }
}

/// Interned-link representation of a flow set, struct-of-arrays
/// throughout (see `DesSim::push_flow`). Grows incrementally: the
/// streaming executor interns links and flows as rounds materialize,
/// recycling flow slots — a fixed [`MAX_PATH_LINKS`] stride per flow —
/// in place, with no per-flow allocation at all.
#[derive(Default)]
struct Dense {
    link_ids: Vec<LinkId>,
    /// Universe slot each interned link was minted from (resets the
    /// [`LinkMap`] without re-deriving indices).
    link_uids: Vec<u32>,
    /// Effective capacity per link (degraded bw + NIC-eff caps,
    /// rescaled in place when a fault event fires).
    cap: Vec<f64>,
    /// Running min of every NIC-eff cap applied to this link
    /// (`INFINITY` when none): lets a fault recompute
    /// `cap = (bw * multipliers).min(nic_min)` without replaying the
    /// flow set. `min` is order-independent and exact in f64, so the
    /// recomputed value equals the from-scratch interning bit for bit.
    nic_min: Vec<f64>,
    /// Live fault multiplier per link (`fabric::faults`): consulted when
    /// a link is interned mid-run (streaming materialization, reroute)
    /// so new flows see post-fault capacities. A `BTreeMap` for the
    /// same reason as [`DesOpts::degraded`] (detlint R1). Empty in
    /// fault-free runs — the intern path never touches it.
    fault_mult: BTreeMap<LinkId, f64>,
    /// Per flow: dense link ids along its path, [`MAX_PATH_LINKS`]
    /// slots per flow (only the first `flow_len` are meaningful).
    flow_links: Vec<u32>,
    /// Per flow: number of links on its path.
    flow_len: Vec<u8>,
    /// Per flow: issue-rate cap.
    flow_cap: Vec<f64>,
    /// Per flow: ejection (last) link id.
    flow_last: Vec<u32>,
}

impl Dense {
    /// Dense link ids along flow `fi`'s path.
    #[inline]
    fn links_of(&self, fi: usize) -> &[u32] {
        let o = fi * MAX_PATH_LINKS;
        &self.flow_links[o..o + self.flow_len[fi] as usize]
    }

    /// Clear for the next run, keeping every allocation.
    fn reset(&mut self) {
        self.link_ids.clear();
        self.link_uids.clear();
        self.cap.clear();
        self.nic_min.clear();
        self.fault_mult.clear();
        self.flow_links.clear();
        self.flow_len.clear();
        self.flow_cap.clear();
        self.flow_last.clear();
    }
}

/// Mutable solver state shared by every executor: per-flow progress, the
/// per-link active-flow index, congestion bookkeeping and the scratch
/// reused across events. [`DesSim::run`], [`DesSim::run_dag`] /
/// [`DesSim::run_dag_oracle`] and the streaming [`DesSim::run_stream`]
/// all drive the same per-event solve block ([`DesSim::solve_batch`])
/// over this state, so the max-min arithmetic, entry-queueing model and
/// contributor/victim classification exist exactly once.
#[derive(Default)]
struct SolveState {
    remaining: Vec<f64>,
    rate: Vec<f64>,
    last_sync: Vec<f64>,
    queue_penalty: Vec<f64>,
    active: Vec<bool>,
    done: Vec<bool>,
    epoch: Vec<u32>,
    /// Retry attempts consumed so far ([`super::FaultPolicy`]'s
    /// `RetryBackoff`); zero outside fault runs.
    retry: Vec<u32>,
    /// Per-link list of active flows (the incremental component index).
    link_flows: Vec<Vec<u32>>,
    eject_count: Vec<u32>,
    // ---- component-walk scratch, reused across events ----
    link_seen: Vec<u32>,
    flow_seen: Vec<u32>,
    stamp: u32,
    contributors: FxHashSet<usize>,
    victims: FxHashSet<usize>,
    /// Classification counts banked when a slot is recycled (streaming):
    /// the sets are keyed by slot, so a recycled slot's previous
    /// occupant must be counted out before reuse.
    banked_contributors: usize,
    banked_victims: usize,
    /// The current batch's affected flows, partitioned into
    /// link-disjoint components: component `i` is
    /// `comp[comp_ends[i-1]..comp_ends[i]]`.
    comp: Vec<usize>,
    comp_ends: Vec<usize>,
    lstack: Vec<u32>,
    // ---- solve statistics (reported on every result) ----
    batches: usize,
    components: usize,
    fanned: usize,
    fastpath: usize,
}

impl SolveState {
    /// Clear for the next run, keeping every allocation. Per-link arrays
    /// keep their length (zero-filled) — `grow_links` only ever grows
    /// them, and link ids of the next run index below its own link
    /// count, so longer-than-needed tails are simply never touched. A
    /// reset state is observationally identical to a fresh one.
    fn reset(&mut self) {
        self.remaining.clear();
        self.rate.clear();
        self.last_sync.clear();
        self.queue_penalty.clear();
        self.active.clear();
        self.done.clear();
        self.epoch.clear();
        self.retry.clear();
        self.flow_seen.clear();
        for v in &mut self.link_flows {
            v.clear();
        }
        self.eject_count.fill(0);
        self.link_seen.fill(0);
        self.stamp = 0;
        self.contributors.clear();
        self.victims.clear();
        self.banked_contributors = 0;
        self.banked_victims = 0;
        self.comp.clear();
        self.comp_ends.clear();
        self.lstack.clear();
        self.batches = 0;
        self.components = 0;
        self.fanned = 0;
        self.fastpath = 0;
    }

    /// Unique contributor flows so far (banked recycled slots + live).
    fn contributor_count(&self) -> usize {
        self.banked_contributors + self.contributors.len()
    }

    /// Unique victim flows so far (banked recycled slots + live).
    fn victim_count(&self) -> usize {
        self.banked_victims + self.victims.len()
    }

    /// Append one flow slot (streaming materialization).
    fn push_flow(&mut self, bytes: f64) -> usize {
        let i = self.remaining.len();
        self.remaining.push(bytes);
        self.rate.push(0.0);
        self.last_sync.push(0.0);
        self.queue_penalty.push(f64::NAN);
        self.active.push(false);
        self.done.push(false);
        self.epoch.push(0);
        self.retry.push(0);
        self.flow_seen.push(0);
        i
    }

    /// Reset a retired flow slot for a new transfer (streaming). The
    /// epoch keeps counting upward, so stale heap events scheduled for
    /// the previous occupant stay invalidated.
    fn recycle_flow(&mut self, i: usize, bytes: f64) {
        if self.contributors.remove(&i) {
            self.banked_contributors += 1;
        }
        if self.victims.remove(&i) {
            self.banked_victims += 1;
        }
        self.remaining[i] = bytes;
        self.rate[i] = 0.0;
        self.last_sync[i] = 0.0;
        self.queue_penalty[i] = f64::NAN;
        self.active[i] = false;
        self.done[i] = false;
        self.epoch[i] = self.epoch[i].wrapping_add(1);
        self.retry[i] = 0;
    }

    /// Grow per-link state after new links were interned.
    fn grow_links(&mut self, n_links: usize) {
        self.link_flows.resize_with(n_links, Vec::new);
        self.eject_count.resize(n_links, 0);
        self.link_seen.resize(n_links, 0);
    }

    /// Flow `fi`'s bulk left the fabric: drop it from the link index.
    fn complete(&mut self, d: &Dense, fi: usize) {
        self.done[fi] = true;
        self.active[fi] = false;
        for &l in d.links_of(fi) {
            let lf = &mut self.link_flows[l as usize];
            if let Some(pos) = lf.iter().position(|&x| x == fi as u32) {
                lf.swap_remove(pos);
            }
        }
        self.eject_count[d.flow_last[fi] as usize] -= 1;
    }

    /// Flow `fi` enters the fabric now.
    fn arrive(&mut self, d: &Dense, fi: usize, now: f64) {
        self.active[fi] = true;
        self.last_sync[fi] = now;
        for &l in d.links_of(fi) {
            self.link_flows[l as usize].push(fi as u32);
        }
        self.eject_count[d.flow_last[fi] as usize] += 1;
    }

    /// Pull flow `fi` off the fabric mid-transfer (fault policy sweep):
    /// sync the bytes delivered so far, drop it from the link index and
    /// invalidate its projected completion. Unlike [`Self::complete`]
    /// the flow is *not* done — it may re-arrive (reroute, retry) with
    /// its remaining bytes intact, or be marked failed by the caller.
    fn detach(&mut self, d: &Dense, fi: usize, now: f64) {
        self.remaining[fi] = (self.remaining[fi]
            - self.rate[fi] * (now - self.last_sync[fi]))
            .max(0.0);
        self.last_sync[fi] = now;
        self.rate[fi] = 0.0;
        self.active[fi] = false;
        self.epoch[fi] = self.epoch[fi].wrapping_add(1);
        for &l in d.links_of(fi) {
            let lf = &mut self.link_flows[l as usize];
            if let Some(pos) = lf.iter().position(|&x| x == fi as u32) {
                lf.swap_remove(pos);
            }
        }
        self.eject_count[d.flow_last[fi] as usize] -= 1;
    }
}

/// Per-component solve scratch: the link- and flow-indexed arrays the
/// max-min filling, entry-queueing and classification blocks mark and
/// then restore to zero. The serial path owns one inside [`DesScratch`];
/// the parallel path gives each worker its own from the scratch's
/// persistent pool ([`crate::campaign::pool::par_map_pooled`] over
/// `DesScratch::par_cscratch`), so components never contend and the
/// multi-MB link-indexed arrays are zero-built once, not per batch. All
/// entries are zero between uses — each block cleans up exactly what it
/// touched, which is also what makes pooling safe.
#[derive(Default)]
struct CompScratch {
    rem_cap: Vec<f64>,
    count: Vec<u32>,
    /// Per-flow 1-based component-slot tags (`maxmin_component`).
    slot: Vec<u32>,
    touched: Vec<u32>,
    inflight: Vec<f64>,
    contaminated: Vec<bool>,
}

impl CompScratch {
    fn grow(&mut self, n_links: usize, n_flows: usize) {
        if self.rem_cap.len() < n_links {
            self.rem_cap.resize(n_links, 0.0);
            self.count.resize(n_links, 0);
            self.inflight.resize(n_links, 0.0);
            self.contaminated.resize(n_links, false);
        }
        if self.slot.len() < n_flows {
            self.slot.resize(n_flows, 0);
        }
    }
}

/// What one component's solve produced — merged into [`SolveState`]
/// serially, in component-id order, so the commit is deterministic no
/// matter how the components were scheduled.
struct CompOut {
    /// Max-min rates aligned with the component's flow list.
    rates: Vec<f64>,
    /// `(flow, entry queueing delay)` for flows priced this batch.
    penalties: Vec<(u32, f64)>,
    contributors: Vec<u32>,
    victims: Vec<u32>,
    /// Rates came from the single-bottleneck fast path (statistics only;
    /// the rates themselves are bit-identical to the general waterfill).
    fast: bool,
}

impl<'t> DesSim<'t> {
    pub fn new(topo: &'t Topology, opts: DesOpts) -> Self {
        Self { topo, opts }
    }

    /// Open a [`DesSession`] over a caller-owned scratch arena — the
    /// unified entry into every execution mode of the simulator:
    ///
    /// ```text
    /// sim.session(&mut scratch).solve(&timed)            // flat flow set
    /// sim.session(&mut scratch).simultaneous(&routed)    // all start at 0
    /// sim.session(&mut scratch).dag(&workload)           // closed-loop DAG
    /// sim.session(&mut scratch).stream(&mut source)      // windowed stream
    /// sim.session(&mut scratch).stream_sink(&mut source, sink)
    /// sim.session(&mut scratch).opts(custom).dag(&workload)
    /// ```
    ///
    /// The legacy `run*` entry points (`run`, `run_with`, `run_dag`,
    /// `run_dag_with`, `run_simultaneous_with`, `run_stream_with`,
    /// `run_stream_sink`) are kept as `#[doc(hidden)]` wrappers over the
    /// same implementations — `tests/session_api.rs` proves each one
    /// bit-identical to its session-built twin.
    pub fn session<'a, 's>(
        &'a self,
        scratch: &'s mut DesScratch,
    ) -> DesSession<'a, 's, 't> {
        DesSession { sim: self, scratch, opts: None }
    }

    /// The options this simulator was built with (read-only). Lets
    /// adapters that drive a session — e.g. [`super::run_open_loop`] —
    /// observe the armed [`DesOpts::policies`] without threading a
    /// second copy through their own signatures.
    pub fn opts(&self) -> &DesOpts {
        &self.opts
    }

    fn link_cap(&self, l: &LinkId) -> f64 {
        let base = self.topo.link_bw(l);
        base * self.opts.degraded.get(l).copied().unwrap_or(1.0)
    }

    /// Intern one routed flow into `d`, growing per-link state as new
    /// links appear. `slot = Some(i)` reuses the freed flow slot `i`
    /// in place (streaming executor); `None` appends. Capacity rules are
    /// those of the one-shot build: degraded bandwidth, with NIC
    /// endpoint links capped at the effective NIC bandwidth of the
    /// buffer types crossing them (PCIe Gen4 practical limit for host,
    /// Gen4<->Gen5 conversion for GPU buffers — §5.1/Fig 13). The min is
    /// applied per flow as it is interned, so the *final* capacities
    /// equal the two-pass batch computation for any flow order. For the
    /// batch executors that is the whole story (they solve only after
    /// every flow is interned); in `run_stream` a NIC link's cap
    /// mid-run reflects only the flows materialized so far — identical
    /// to the batch value from t=0 whenever the workload uses one
    /// `BufLoc` throughout (see the `run_stream` caveat).
    fn push_flow(
        &self,
        d: &mut Dense,
        map: &mut LinkMap,
        rf: &RoutedFlow,
        slot: Option<usize>,
    ) -> usize {
        let n = rf.path.links.len();
        assert!(
            (1..=MAX_PATH_LINKS).contains(&n),
            "flow path has {n} links (1..={MAX_PATH_LINKS} supported)"
        );
        let c = &self.topo.cfg;
        let fcap = match rf.flow.buf {
            super::BufLoc::Host => c.rank_issue_bw_host,
            super::BufLoc::Gpu => c.rank_issue_bw_gpu,
        };
        let eff = match rf.flow.buf {
            super::BufLoc::Host => c.nic_eff_bw_host,
            super::BufLoc::Gpu => c.nic_eff_bw_gpu,
        };
        let mut ls = [0u32; MAX_PATH_LINKS];
        for (k, l) in rf.path.links.iter().enumerate() {
            let u = self.topo.link_index(l) as usize;
            let mut id = map.ids[u];
            if id == u32::MAX {
                id = d.link_ids.len() as u32;
                map.ids[u] = id;
                d.link_ids.push(*l);
                d.link_uids.push(u as u32);
                let mut c = self.link_cap(l);
                // mid-run interning (streaming, reroute) sees the live
                // fault overlay; empty in fault-free runs, so the
                // healthy intern path is untouched bit for bit
                if let Some(&m) = d.fault_mult.get(l) {
                    c *= m;
                }
                d.cap.push(c);
                d.nic_min.push(f64::INFINITY);
            }
            if matches!(l, LinkId::NicUp(_) | LinkId::NicDown(_)) {
                d.cap[id as usize] = d.cap[id as usize].min(eff);
                d.nic_min[id as usize] = d.nic_min[id as usize].min(eff);
            }
            ls[k] = id;
        }
        let last = ls[n - 1];
        match slot {
            Some(i) => {
                let o = i * MAX_PATH_LINKS;
                d.flow_links[o..o + MAX_PATH_LINKS].copy_from_slice(&ls);
                d.flow_len[i] = n as u8;
                d.flow_cap[i] = fcap;
                d.flow_last[i] = last;
                i
            }
            None => {
                d.flow_links.extend_from_slice(&ls);
                d.flow_len.push(n as u8);
                d.flow_cap.push(fcap);
                d.flow_last.push(last);
                d.flow_len.len() - 1
            }
        }
    }

    /// Deterministic route repair for the `Reroute` fault policy: the
    /// first minimal candidate (stable candidate order) whose links are
    /// all up — down means a live fault multiplier of `0.0` (a
    /// statically-degraded-to-zero link counts too). `None` when every
    /// candidate crosses a down link (e.g. the flow's own NIC died).
    fn reroute_path(&self, d: &Dense, rf: &RoutedFlow) -> Option<Path> {
        let link_up = |l: &LinkId| {
            self.link_cap(l) * d.fault_mult.get(l).copied().unwrap_or(1.0)
                > 0.0
        };
        self.topo
            .minimal_candidates(rf.flow.src_nic, rf.flow.dst_nic)
            .into_iter()
            .find(|p| p.links.iter().all(link_up))
    }

    /// Deterministic hedge route ([`DesOpts::policies`]): the first
    /// minimal candidate (stable candidate order) whose links are all
    /// up *and* which shares no fabric link with the primary path. The
    /// endpoint NIC injection/ejection links are necessarily shared, so
    /// they are exempt — disjointness is about the switch-to-switch
    /// segments a flap can take down. `None` when no such route exists
    /// (single-path topology, or everything else is down): the hedge is
    /// silently skipped and the primary keeps running.
    fn hedge_path(&self, d: &Dense, rf: &RoutedFlow) -> Option<Path> {
        let link_up = |l: &LinkId| {
            self.link_cap(l) * d.fault_mult.get(l).copied().unwrap_or(1.0)
                > 0.0
        };
        let disjoint = |l: &LinkId| {
            matches!(l, LinkId::NicUp(_) | LinkId::NicDown(_))
                || !rf.path.links.contains(l)
        };
        self.topo
            .minimal_candidates(rf.flow.src_nic, rf.flow.dst_nic)
            .into_iter()
            .find(|p| p.links.iter().all(|l| link_up(l) && disjoint(l)))
    }

    /// One retry-backoff step for flow `fu`: re-arm the timer at
    /// `timeout * backoff^attempt` (consuming one attempt), or mark the
    /// flow failed once `max_retries` attempts are spent. The scheduled
    /// [`EV_RETRY`] carries the post-detach epoch, so it stays valid
    /// exactly until the flow moves again.
    ///
    /// When a [`DesOpts::policies`] retry budget is armed (`budgets` is
    /// `Some`), each re-arm also consumes one unit of the flow's
    /// class-shared budget; a spent budget fails the flow *now* instead
    /// of re-arming — retry storms cannot amplify an outage past the
    /// budget (EXPERIMENTS.md §Graceful degradation).
    #[allow(clippy::too_many_arguments)]
    fn retry_or_fail(
        &self,
        policy: &FaultPolicy,
        st: &mut SolveState,
        heap: &mut BinaryHeap<Reverse<Ev>>,
        now: f64,
        fu: u32,
        failed: &mut Vec<u32>,
        class: u8,
        budgets: &mut Option<Vec<f64>>,
    ) {
        let (timeout, backoff, max_retries) = match *policy {
            FaultPolicy::RetryBackoff { timeout, backoff, max_retries } => {
                (timeout, backoff, max_retries)
            }
            _ => unreachable!("retry events only exist under RetryBackoff"),
        };
        let fi = fu as usize;
        if st.retry[fi] >= max_retries {
            st.done[fi] = true;
            failed.push(fu);
            return;
        }
        if let Some(b) = budgets {
            if let Some(left) = b.get_mut(class as usize) {
                if *left < 1.0 {
                    st.done[fi] = true;
                    failed.push(fu);
                    return;
                }
                if left.is_finite() {
                    *left -= 1.0;
                }
            }
        }
        let wait = timeout * backoff.powi(st.retry[fi] as i32);
        st.retry[fi] += 1;
        heap.push(Reverse(Ev {
            t: now + wait,
            kind: EV_RETRY,
            flow: fu,
            epoch: st.epoch[fi],
        }));
    }

    /// Execute every fault event and retry wake-up due at `now` — the
    /// [`EV_FAULT`] hook shared by all three executors.
    ///
    /// In order: (1) each due fault (schedule order) rescales its links'
    /// dense capacities through the overlay, `cap = (bw * static *
    /// fault).min(nic_min)`; (2) in-flight flows crossing a link that is
    /// now down are swept under the schedule's [`FaultPolicy`] —
    /// detached, then rerouted (re-arriving now), re-armed for retry, or
    /// failed (`st.done`, pushed to `failed` for the caller's result
    /// bookkeeping); (3) due retries re-check their path against the
    /// *post*-fault capacities (a recovery sharing the timestamp lets
    /// the retry through). Tie-break with completions: a flow whose
    /// completion event shares the fault's timestamp is skipped by the
    /// sweep and completes — delivered bytes are never retroactively
    /// destroyed. `faulted` receives the re-solve seeds; `rf_of(fi)`
    /// recovers flow `fi`'s routed flow for the reroute policy.
    /// `flow_class` maps slots to service classes (empty outside the
    /// streaming tier: everything class 0) and `budgets` carries the
    /// live per-class retry budgets of an armed [`DesOpts::policies`]
    /// (`None` = unbounded).
    #[allow(clippy::too_many_arguments)]
    fn fault_tick(
        &self,
        fs: &FaultSchedule,
        due: &[u32],
        retry_due: &[u32],
        d: &mut Dense,
        map: &mut LinkMap,
        st: &mut SolveState,
        heap: &mut BinaryHeap<Reverse<Ev>>,
        now: f64,
        completions: &[usize],
        arrivals: &mut Vec<usize>,
        faulted: &mut Vec<usize>,
        failed: &mut Vec<u32>,
        rf_of: &mut dyn FnMut(usize) -> RoutedFlow,
        flow_class: &[u8],
        budgets: &mut Option<Vec<f64>>,
    ) {
        // ---- (1) capacity changes, in schedule order ----
        let mut mults: Vec<(LinkId, f64)> = Vec::new();
        let mut changed: Vec<u32> = Vec::new();
        for &ei in due {
            mults.clear();
            fs.events[ei as usize]
                .kind
                .link_multipliers(self.topo.cfg.nics_per_node, &mut mults);
            for &(l, m) in &mults {
                d.fault_mult.insert(l, m);
                let id = map.ids[self.topo.link_index(&l) as usize];
                if id == u32::MAX {
                    continue; // no flow ever crossed it: overlay only
                }
                let c = (self.link_cap(&l) * m)
                    .min(d.nic_min[id as usize]);
                if c.to_bits() != d.cap[id as usize].to_bits() {
                    d.cap[id as usize] = c;
                    changed.push(id);
                }
            }
        }
        changed.sort_unstable();
        changed.dedup();

        // ---- (2) policy sweep over in-flight flows on down links ----
        let mut hits: Vec<u32> = Vec::new();
        for &id in &changed {
            if d.cap[id as usize] == 0.0 {
                hits.extend_from_slice(&st.link_flows[id as usize]);
            }
        }
        hits.sort_unstable();
        hits.dedup();
        for &fu in &hits {
            let fi = fu as usize;
            if st.done[fi] || !st.active[fi] || completions.contains(&fi) {
                continue; // completion at this instant wins (tie-break)
            }
            st.detach(d, fi, now);
            // survivors sharing the swept flow's links re-share its
            // freed capacity: seed their components
            for &l in d.links_of(fi) {
                faulted.extend(
                    st.link_flows[l as usize].iter().map(|&x| x as usize),
                );
            }
            match fs.policy {
                FaultPolicy::Abort => {
                    st.done[fi] = true;
                    failed.push(fu);
                }
                FaultPolicy::RetryBackoff { .. } => {
                    let class = flow_class.get(fi).copied().unwrap_or(0);
                    self.retry_or_fail(
                        &fs.policy, st, heap, now, fu, failed, class, budgets,
                    );
                }
                FaultPolicy::Reroute => {
                    let rf0 = rf_of(fi);
                    match self.reroute_path(d, &rf0) {
                        Some(path) => {
                            let rf = RoutedFlow { flow: rf0.flow, path };
                            self.push_flow(d, map, &rf, Some(fi));
                            st.grow_links(d.cap.len());
                            arrivals.push(fi);
                        }
                        None => {
                            st.done[fi] = true;
                            failed.push(fu);
                        }
                    }
                }
            }
        }

        // ---- (3) retry wake-ups, against post-fault capacities ----
        for &fu in retry_due {
            let fi = fu as usize;
            let still_down = d
                .links_of(fi)
                .iter()
                .any(|&l| d.cap[l as usize] == 0.0);
            if still_down {
                let class = flow_class.get(fi).copied().unwrap_or(0);
                self.retry_or_fail(
                    &fs.policy, st, heap, now, fu, failed, class, budgets,
                );
            } else {
                arrivals.push(fi);
            }
        }

        // ---- every flow still attached to a changed link re-solves
        // its component (degrades, recoveries, freed shares) ----
        for &id in &changed {
            faulted.extend(
                st.link_flows[id as usize].iter().map(|&x| x as usize),
            );
        }
        faulted.sort_unstable();
        faulted.dedup();
    }

    /// Build the dense (interned-link) representation used by the solver.
    /// Link ids are interned ONCE per simulation; the per-event max-min
    /// recomputation then runs on flat vectors — this is the §Perf
    /// optimization that took the 512-flow DES from ~38 ms to single-digit
    /// milliseconds (EXPERIMENTS.md §Perf). Interning itself is now
    /// hash-free: ids come from the [`Topology::link_index`] universe
    /// through a flat [`LinkMap`].
    fn build_dense(&self, flows: &[TimedFlow]) -> Dense {
        let mut d = Dense::default();
        let mut map = LinkMap::default();
        map.ensure(self.topo.link_universe());
        for tf in flows {
            self.push_flow(&mut d, &mut map, &tf.rf, None);
        }
        d
    }

    /// The per-event solve block shared by `run`, `run_dag_impl` and
    /// `run_stream`: component *partitioning* (incremental walk from the
    /// changed flows, or the full active set when `full_resolve`), lazy
    /// byte sync, then — per link-disjoint component — entry-queueing
    /// pricing for new arrivals, exact max-min and congestion
    /// classification ([`DesSim::solve_component`]), and finally a
    /// serial, component-id-ordered merge + rate commit with completion
    /// (re)projection into `heap`. When a batch spans several components
    /// and `opts.solver_threads > 1`, the per-component solves fan out
    /// over [`crate::campaign::pool::par_map_pooled`] worker scratches
    /// (persistent in the [`DesScratch`], warm across batches);
    /// every component's arithmetic is a pure function of the synced
    /// pre-batch state, so the fan-out is bit-identical to the serial
    /// path at any thread count. Completion *effects* — what a finished
    /// flow means (a result row, a DAG node, a dependent release) —
    /// stay with the caller; this block is only the fabric arithmetic,
    /// which is why the three executors price traffic identically.
    #[allow(clippy::too_many_arguments)]
    fn solve_batch(
        &self,
        d: &Dense,
        st: &mut SolveState,
        cs: &mut CompScratch,
        pcs: &mut Vec<CompScratch>,
        wp: &mut Option<crate::campaign::pool::WorkerPool>,
        heap: &mut BinaryHeap<Reverse<Ev>>,
        now: f64,
        completions: &[usize],
        arrivals: &[usize],
        faulted: &[usize],
        full_resolve: bool,
    ) {
        // ---- partition the affected flows into link-disjoint
        // components (or, for the oracle, everything as one) ----
        st.comp.clear();
        st.comp_ends.clear();
        if full_resolve {
            let n = st.active.len();
            st.comp.extend((0..n).filter(|&fi| st.active[fi]));
            if !st.comp.is_empty() {
                st.comp_ends.push(st.comp.len());
            }
        } else {
            st.stamp = st.stamp.wrapping_add(1);
            let stamp = st.stamp;
            st.lstack.clear();
            // each changed flow seeds (at most) one new partition: the
            // closure of flows transitively sharing links. Later seeds
            // whose region was already visited contribute nothing, so
            // partitions are link-disjoint by construction — two flows
            // sharing a link always land in the same partition.
            // `faulted` seeds (flows still attached to a link whose
            // capacity a fault event just changed, plus the survivors
            // sharing links with a swept flow) walk the same closure as
            // completions/arrivals — exactly the components whose
            // capacities changed are re-solved, nothing else.
            for &seed in completions
                .iter()
                .chain(arrivals.iter())
                .chain(faulted.iter())
            {
                let start = st.comp.len();
                for &l in d.links_of(seed) {
                    if st.link_seen[l as usize] != stamp {
                        st.link_seen[l as usize] = stamp;
                        st.lstack.push(l);
                    }
                }
                while let Some(l) = st.lstack.pop() {
                    for &fu in &st.link_flows[l as usize] {
                        let fi = fu as usize;
                        if st.flow_seen[fi] != stamp {
                            st.flow_seen[fi] = stamp;
                            st.comp.push(fi);
                            for &ll in d.links_of(fi) {
                                if st.link_seen[ll as usize] != stamp {
                                    st.link_seen[ll as usize] = stamp;
                                    st.lstack.push(ll);
                                }
                            }
                        }
                    }
                }
                if st.comp.len() > start {
                    st.comp_ends.push(st.comp.len());
                }
            }
        }
        if st.comp.is_empty() {
            return; // isolated completion: nothing shares its links
        }
        st.batches += 1;
        st.components += st.comp_ends.len();
        #[cfg(debug_assertions)]
        self.debug_check_partition(d, st);

        // ---- lazily sync transferred bytes (serial: per-flow writes
        // the component solves below read) ----
        for &fi in &st.comp {
            st.remaining[fi] = (st.remaining[fi]
                - st.rate[fi] * (now - st.last_sync[fi]))
                .max(0.0);
            st.last_sync[fi] = now;
        }

        // ---- per-component solve: fan out when the batch spans
        // several components and carries enough work ----
        let n_comp = st.comp_ends.len();
        let fan_out = self.opts.solver_threads > 1
            && n_comp >= 2
            && st.comp.len() >= PAR_SOLVE_MIN_FLOWS;
        let outs: Vec<CompOut> = if fan_out {
            st.fanned += 1;
            let mut ranges = Vec::with_capacity(n_comp);
            let mut start = 0usize;
            for &end in &st.comp_ends {
                ranges.push((start, end));
                start = end;
            }
            let stx: &SolveState = st;
            // long-lived parked workers: spawned on the first fanned
            // batch, then every later batch only pays a condvar wake
            let pool = crate::campaign::pool::ensure_pool(
                wp,
                self.opts.solver_threads,
            );
            crate::campaign::pool::par_map_on(
                pool,
                &ranges,
                self.opts.solver_threads,
                pcs,
                |&(a, b), w: &mut CompScratch| {
                    self.solve_component(d, stx, &stx.comp[a..b], w)
                },
            )
        } else {
            let mut outs = Vec::with_capacity(n_comp);
            let mut start = 0usize;
            for &end in &st.comp_ends {
                outs.push(self.solve_component(d, st, &st.comp[start..end], cs));
                start = end;
            }
            outs
        };

        // ---- deterministic merge + commit, in component-id order ----
        let mut start = 0usize;
        for (ci, out) in outs.into_iter().enumerate() {
            let end = st.comp_ends[ci];
            if out.fast {
                st.fastpath += 1;
            }
            for &(fi, pen) in &out.penalties {
                st.queue_penalty[fi as usize] = pen;
            }
            for &fi in &out.contributors {
                st.contributors.insert(fi as usize);
            }
            for &fi in &out.victims {
                st.victims.insert(fi as usize);
            }
            for (idx, &fi) in st.comp[start..end].iter().enumerate() {
                st.rate[fi] = out.rates[idx];
                st.epoch[fi] = st.epoch[fi].wrapping_add(1);
                let t_fin = if st.remaining[fi] <= 1e-6 {
                    now // mirrors the oracle's completion threshold
                } else if st.rate[fi] > 0.0 {
                    now + st.remaining[fi] / st.rate[fi]
                } else {
                    f64::INFINITY
                };
                if t_fin.is_finite() {
                    heap.push(Reverse(Ev {
                        t: t_fin,
                        kind: EV_COMPLETION,
                        flow: fi as u32,
                        epoch: st.epoch[fi],
                    }));
                }
            }
            start = end;
        }
        #[cfg(debug_assertions)]
        self.debug_check_capacity(d, st);
    }

    /// `debug_assertions` sanitizer for the PR 5 disjointness argument:
    /// the partition walk's transitive closure must place two flows
    /// sharing any link in the SAME component (that is what makes the
    /// per-component solves independent and the fan-out bit-identical),
    /// and no flow in two components. Checked at every batch in debug
    /// builds — the prose proof in EXPERIMENTS.md becomes a property.
    #[cfg(debug_assertions)]
    fn debug_check_partition(&self, d: &Dense, st: &SolveState) {
        let mut link_comp: FxHashMap<u32, usize> = FxHashMap::default();
        let mut flow_comp: FxHashMap<usize, usize> = FxHashMap::default();
        let mut start = 0usize;
        for (ci, &end) in st.comp_ends.iter().enumerate() {
            for &fi in &st.comp[start..end] {
                if let Some(prev) = flow_comp.insert(fi, ci) {
                    panic!(
                        "solve_batch partition: flow {fi} in components \
                         {prev} and {ci}"
                    );
                }
                for &l in d.links_of(fi) {
                    if let Some(prev) = link_comp.insert(l, ci) {
                        assert!(
                            prev == ci,
                            "solve_batch partition not link-disjoint: \
                             dense link {l} touched by components {prev} \
                             and {ci}"
                        );
                    }
                }
            }
            start = end;
        }
    }

    /// `debug_assertions` sanitizer: after the merge/commit, the summed
    /// committed rates of the active flows on every link touched by this
    /// batch must not exceed the link's effective capacity (1e-9
    /// relative slack for the waterfill's float arithmetic). Partitions
    /// are link-closed, so `link_flows` holds every rate sharing the
    /// link — the sum is the whole subscription, not a sample.
    #[cfg(debug_assertions)]
    fn debug_check_capacity(&self, d: &Dense, st: &SolveState) {
        let mut seen: FxHashSet<u32> = FxHashSet::default();
        for &fi in &st.comp {
            for &l in d.links_of(fi) {
                if !seen.insert(l) {
                    continue;
                }
                let lu = l as usize;
                let sum: f64 = st.link_flows[lu]
                    .iter()
                    .map(|&fu| fu as usize)
                    .filter(|&f2| st.active[f2])
                    .map(|f2| st.rate[f2])
                    .sum();
                let cap = d.cap[lu];
                assert!(
                    sum <= cap * (1.0 + 1e-9) + 1e-12,
                    "committed rates oversubscribe dense link {l}: \
                     {sum} > cap {cap}"
                );
            }
        }
    }

    /// One component's solve: entry-queueing pricing, exact max-min and
    /// congestion classification over `comp` — a pure function of the
    /// (already byte-synced) `st` and the worker-owned `cs`, which is
    /// what lets [`DesSim::solve_batch`] run components concurrently
    /// with bit-identical results. Nothing outside `cs` is written; the
    /// produced [`CompOut`] is merged serially by the caller.
    fn solve_component(
        &self,
        d: &Dense,
        st: &SolveState,
        comp: &[usize],
        cs: &mut CompScratch,
    ) -> CompOut {
        let thr = self.opts.incast_threshold as u32;
        cs.grow(d.cap.len(), st.remaining.len());
        let mut penalties: Vec<(u32, f64)> = Vec::new();
        let mut contributors: Vec<u32> = Vec::new();
        let mut victims: Vec<u32> = Vec::new();

        // ---- queueing delay seen by newly arrived flows (Fig 5 shape):
        // in-flight bytes of OTHER flows on each hop, capped by the
        // switch queue; with congestion management incast contributors
        // are held at injection and excluded ----
        if comp.iter().any(|&fi| st.queue_penalty[fi].is_nan()) {
            for &fi in comp {
                if self.opts.congestion_mgmt
                    && st.eject_count[d.flow_last[fi] as usize] >= thr
                {
                    continue;
                }
                for &l in d.links_of(fi) {
                    cs.inflight[l as usize] += st.remaining[fi];
                }
            }
            for &fi in comp {
                if !st.queue_penalty[fi].is_nan() {
                    continue;
                }
                let mut pen = 0.0;
                for &l in d.links_of(fi) {
                    let queued = (cs.inflight[l as usize] - st.remaining[fi])
                        .max(0.0)
                        .min(self.opts.queue_cap_bytes);
                    pen += queued / d.cap[l as usize].max(1.0);
                }
                penalties.push((fi as u32, pen));
            }
            for &fi in comp {
                for &l in d.links_of(fi) {
                    cs.inflight[l as usize] = 0.0;
                }
            }
        }

        // ---- exact max-min over the component ----
        let (mut rates, fast) = self.maxmin_component(
            d,
            comp,
            &st.link_flows,
            &mut cs.rem_cap,
            &mut cs.count,
            &mut cs.slot,
            &mut cs.touched,
        );

        // ---- congestion classification (incast ejection links) ----
        let any_incast = comp
            .iter()
            .any(|&fi| st.eject_count[d.flow_last[fi] as usize] >= thr);
        if any_incast {
            for &fi in comp {
                if st.eject_count[d.flow_last[fi] as usize] >= thr {
                    contributors.push(fi as u32);
                    for &l in d.links_of(fi) {
                        cs.contaminated[l as usize] = true;
                    }
                }
            }
            if !self.opts.congestion_mgmt {
                // back-pressure spreads: victims crossing contaminated
                // links are slowed
                for (idx, &fi) in comp.iter().enumerate() {
                    if st.eject_count[d.flow_last[fi] as usize] >= thr {
                        continue; // contributor, already fair-shared
                    }
                    if d.links_of(fi)
                        .iter()
                        .any(|&l| cs.contaminated[l as usize])
                    {
                        rates[idx] *= self.opts.victim_penalty;
                        victims.push(fi as u32);
                    }
                }
            }
            for &fi in comp {
                for &l in d.links_of(fi) {
                    cs.contaminated[l as usize] = false;
                }
            }
        }
        CompOut { rates, penalties, contributors, victims, fast }
    }

    /// Exact max-min fair rates with per-flow caps (progressive filling)
    /// over the dense representation. `scratch` vectors are reused across
    /// events; `active` holds flow indices. Returns rates aligned with
    /// `active`.
    ///
    /// `rem_cap[l]` is the capacity not yet claimed by fixed flows, so a
    /// link's saturation share is simply `rem_cap / count` — independent
    /// of any global water level. (The original implementation tracked a
    /// global `level` and debited `rate - level`, which let allocations
    /// drift with the fix order and over-commit links shared by flows
    /// fixed after an unrelated cap-fix; see EXPERIMENTS.md §Perf. The
    /// fixpoint here is the unique max-min allocation, which is also what
    /// makes the incremental solver's component-local re-solve exact.)
    #[allow(clippy::too_many_arguments)]
    fn maxmin_dense(
        &self,
        d: &Dense,
        active: &[usize],
        rem_cap: &mut [f64],
        count: &mut [u32],
        touched: &mut Vec<u32>,
    ) -> Vec<f64> {
        let n = active.len();
        let mut rate = vec![f64::NAN; n];
        let mut fixed = vec![false; n];
        touched.clear();
        for &fi in active {
            for &l in d.links_of(fi) {
                let li = l as usize;
                if count[li] == 0 {
                    touched.push(l);
                    rem_cap[li] = d.cap[li];
                }
                count[li] += 1;
            }
        }
        let mut n_fixed = 0;
        while n_fixed < n {
            // next binding constraint: a link's fair share or a flow cap
            let mut best_link: Option<(u32, f64)> = None;
            for &l in touched.iter() {
                let li = l as usize;
                if count[li] == 0 {
                    continue;
                }
                let fair = rem_cap[li].max(0.0) / count[li] as f64;
                if best_link.map_or(true, |(_, f)| fair < f) {
                    best_link = Some((l, fair));
                }
            }
            let mut best_flow: Option<(usize, f64)> = None;
            for (idx, &fi) in active.iter().enumerate() {
                if !fixed[idx] {
                    let c = d.flow_cap[fi];
                    if best_flow.map_or(true, |(_, f)| c < f) {
                        best_flow = Some((idx, c));
                    }
                }
            }
            let link_level = best_link.map(|(_, f)| f).unwrap_or(f64::INFINITY);
            let flow_level = best_flow.map(|(_, f)| f).unwrap_or(f64::INFINITY);
            if flow_level <= link_level {
                let (idx, c) = best_flow.unwrap();
                rate[idx] = c;
                fixed[idx] = true;
                n_fixed += 1;
                for &l in d.links_of(active[idx]) {
                    rem_cap[l as usize] -= c;
                    count[l as usize] -= 1;
                }
            } else {
                let (l, fair) = best_link.unwrap();
                // fix every unfixed flow crossing l at `fair`
                for (idx, &fi) in active.iter().enumerate() {
                    if !fixed[idx] && d.links_of(fi).contains(&l) {
                        rate[idx] = fair;
                        fixed[idx] = true;
                        n_fixed += 1;
                        for &ll in d.links_of(fi) {
                            rem_cap[ll as usize] -= fair;
                            count[ll as usize] -= 1;
                        }
                    }
                }
                count[l as usize] = 0; // link saturated / dead
            }
        }
        // reset scratch for the next event
        for &l in touched.iter() {
            count[l as usize] = 0;
        }
        rate
    }

    /// Dense-oracle run: full max-min recompute over every active flow at
    /// every event. O(events x flows x links) — correct and simple; the
    /// reference the incremental solver is validated against.
    pub fn run_oracle(&self, flows: &[TimedFlow]) -> DesResult {
        // the flat oracle has no event heap to fire a timeline through;
        // closed-loop oracle runs (`run_dag_oracle`) share the
        // heap-driven implementation and support faults fully
        assert!(
            self.opts.faults.as_ref().map_or(true, |f| f.is_empty()),
            "run_oracle does not support fault schedules"
        );
        let n = flows.len();
        let d = self.build_dense(flows);
        let n_links = d.link_ids.len();
        let mut remaining: Vec<f64> =
            flows.iter().map(|tf| tf.rf.flow.bytes as f64).collect();
        let mut finish = vec![f64::NAN; n];
        let mut done = vec![false; n];
        let mut now = 0.0_f64;
        let mut n_done = 0;
        let mut contributors_set: FxHashSet<usize> = FxHashSet::default();
        let mut victims_set: FxHashSet<usize> = FxHashSet::default();
        // queueing delay each flow observed when it entered the fabric
        let mut queue_penalty = vec![f64::NAN; n];
        // solver scratch, reused across events
        let mut rem_cap = vec![0.0f64; n_links];
        let mut count = vec![0u32; n_links];
        let mut touched: Vec<u32> = Vec::with_capacity(n_links);
        // per-link scratch for incast detection / queue accounting
        let mut eject_count = vec![0u32; n_links];
        let mut inflight = vec![0.0f64; n_links];
        let mut contaminated = vec![false; n_links];

        while n_done < n {
            let active: Vec<usize> = (0..n)
                .filter(|&i| !done[i] && flows[i].start <= now + 1e-15)
                .collect();
            let next_arrival = flows
                .iter()
                .enumerate()
                .filter(|(i, tf)| !done[*i] && tf.start > now + 1e-15)
                .map(|(_, tf)| tf.start)
                .fold(f64::INFINITY, f64::min);
            if active.is_empty() {
                assert!(next_arrival.is_finite(), "deadlock in DES");
                now = next_arrival;
                continue;
            }

            let mut rates = self.maxmin_dense(
                &d, &active, &mut rem_cap, &mut count, &mut touched,
            );

            // congestion classification: incast ejection links
            for &fi in &active {
                eject_count[d.flow_last[fi] as usize] += 1;
            }
            let is_contrib = |fi: usize| {
                eject_count[d.flow_last[fi] as usize]
                    >= self.opts.incast_threshold as u32
            };
            let any_incast =
                active.iter().any(|&fi| is_contrib(fi));

            // --- queueing delay for newly arrived flows (Fig 5 shape) ---
            // in-flight bytes of OTHER flows sitting on each hop, capped by
            // the switch queue. With congestion management the incast
            // contributors are held at injection (their packets do not
            // pile up in the fabric), so they are excluded.
            if active.iter().any(|&fi| queue_penalty[fi].is_nan()) {
                for &fi in &active {
                    if self.opts.congestion_mgmt && is_contrib(fi) {
                        continue;
                    }
                    for &l in d.links_of(fi) {
                        inflight[l as usize] += remaining[fi];
                    }
                }
                for &fi in &active {
                    if !queue_penalty[fi].is_nan() {
                        continue;
                    }
                    let mut pen = 0.0;
                    for &l in d.links_of(fi) {
                        let queued = (inflight[l as usize] - remaining[fi])
                            .max(0.0)
                            .min(self.opts.queue_cap_bytes);
                        pen += queued / d.cap[l as usize].max(1.0);
                    }
                    queue_penalty[fi] = pen;
                }
                for &fi in &active {
                    for &l in d.links_of(fi) {
                        inflight[l as usize] = 0.0;
                    }
                }
            }
            if any_incast {
                for &fi in &active {
                    if is_contrib(fi) {
                        contributors_set.insert(fi);
                        for &l in d.links_of(fi) {
                            contaminated[l as usize] = true;
                        }
                    }
                }
                if !self.opts.congestion_mgmt {
                    // back-pressure spreads: victims crossing contaminated
                    // links are slowed
                    for (idx, &fi) in active.iter().enumerate() {
                        if is_contrib(fi) {
                            continue; // contributor, already fair-shared
                        }
                        if d.links_of(fi)
                            .iter()
                            .any(|&l| contaminated[l as usize])
                        {
                            rates[idx] *= self.opts.victim_penalty;
                            victims_set.insert(fi);
                        }
                    }
                }
                for &fi in &active {
                    for &l in d.links_of(fi) {
                        contaminated[l as usize] = false;
                    }
                }
            }
            for &fi in &active {
                eject_count[d.flow_last[fi] as usize] = 0;
            }

            // time to next completion
            let mut dt = f64::INFINITY;
            for (idx, &fi) in active.iter().enumerate() {
                if rates[idx] > 0.0 {
                    dt = dt.min(remaining[fi] / rates[idx]);
                }
            }
            dt = dt.min(next_arrival - now);
            assert!(dt.is_finite() && dt >= 0.0, "bad dt {dt}");
            let dt = dt.max(1e-12);
            for (idx, &fi) in active.iter().enumerate() {
                remaining[fi] -= rates[idx] * dt;
            }
            now += dt;
            let cm = super::rounds::CostModel::new(self.topo);
            for &fi in &active {
                if remaining[fi] <= 1e-6 && !done[fi] {
                    done[fi] = true;
                    n_done += 1;
                    // completion includes the zero-load message latency
                    // and the queueing delay seen on entry
                    let tf = &flows[fi];
                    finish[fi] = now
                        + cm.msg_latency(&tf.rf.path, tf.rf.flow.bytes,
                            tf.rf.flow.buf)
                        + if queue_penalty[fi].is_nan() { 0.0 }
                          else { queue_penalty[fi] };
                }
            }
        }
        let makespan = finish.iter().cloned().fold(0.0, f64::max);
        DesResult {
            finish,
            makespan,
            contributors: contributors_set.len(),
            victims: victims_set.len(),
            // the dense oracle re-solves the whole system per event —
            // it never runs the incremental batch solve these count
            solve_batches: 0,
            components_solved: 0,
            fastpath_components: 0,
            failed_flows: 0,
        }
    }

    /// Convenience: all flows start at t=0; returns per-flow durations.
    pub fn run_simultaneous(&self, flows: &[RoutedFlow]) -> FlowTimes {
        self.run_simultaneous_with(flows, &mut DesScratch::default())
    }

    /// [`DesSim::run_simultaneous`] over a caller-owned scratch. Legacy
    /// name for [`DesSession::simultaneous`].
    #[doc(hidden)]
    pub fn run_simultaneous_with(
        &self,
        flows: &[RoutedFlow],
        s: &mut DesScratch,
    ) -> FlowTimes {
        self.simultaneous_impl(flows, s)
    }

    /// Implementation behind [`DesSession::simultaneous`] and the legacy
    /// [`DesSim::run_simultaneous`] wrappers.
    fn simultaneous_impl(
        &self,
        flows: &[RoutedFlow],
        s: &mut DesScratch,
    ) -> FlowTimes {
        let timed: Vec<TimedFlow> = flows
            .iter()
            .map(|rf| TimedFlow { rf: rf.clone(), start: 0.0 })
            .collect();
        let res = self.solve_impl(&timed, s);
        FlowTimes::from_vec(res.finish)
    }

    /// Oracle variant of [`run_simultaneous`]: dense full recompute at
    /// every event. Reachable from integration tests and benches.
    pub fn run_simultaneous_oracle(&self, flows: &[RoutedFlow]) -> FlowTimes {
        let timed: Vec<TimedFlow> = flows
            .iter()
            .map(|rf| TimedFlow { rf: rf.clone(), start: 0.0 })
            .collect();
        let res = self.run_oracle(&timed);
        FlowTimes::from_vec(res.finish)
    }

    /// Run the simulation with the **incremental** solver; `flows` keep
    /// their input order in the result.
    ///
    /// Per-flow rates persist between events; at each arrival/completion
    /// only the affected component (flows transitively sharing links with
    /// the changed flows) is re-solved, transferred bytes are synced
    /// lazily per flow, and completions are projected into an event heap.
    /// Components are link-disjoint, so every other flow's max-min rate —
    /// and therefore its projected completion — is unchanged by
    /// construction. Produces the same max-min fixpoint as
    /// [`DesSim::run_oracle`] (unique given caps + capacities), with
    /// finish times equal to floating-point noise.
    /// Legacy name for [`DesSession::solve`] over a throwaway scratch.
    #[doc(hidden)]
    pub fn run(&self, flows: &[TimedFlow]) -> DesResult {
        self.solve_impl(flows, &mut DesScratch::default())
    }

    /// [`DesSim::run`] over a caller-owned [`DesScratch`]: identical
    /// results, no per-call arena allocation. Legacy name for
    /// [`DesSession::solve`].
    #[doc(hidden)]
    pub fn run_with(&self, flows: &[TimedFlow], s: &mut DesScratch)
        -> DesResult {
        self.solve_impl(flows, s)
    }

    /// Implementation behind [`DesSession::solve`] and the legacy
    /// [`DesSim::run`] / [`DesSim::run_with`] wrappers.
    fn solve_impl(&self, flows: &[TimedFlow], s: &mut DesScratch)
        -> DesResult {
        s.reset();
        s.map.ensure(self.topo.link_universe());
        let n = flows.len();
        if n == 0 {
            return DesResult {
                finish: Vec::new(),
                makespan: 0.0,
                contributors: 0,
                victims: 0,
                solve_batches: 0,
                components_solved: 0,
                fastpath_components: 0,
                failed_flows: 0,
            };
        }
        for tf in flows {
            self.push_flow(&mut s.d, &mut s.map, &tf.rf, None);
            s.st.push_flow(tf.rf.flow.bytes as f64);
        }
        s.st.grow_links(s.d.cap.len());
        let cm = super::rounds::CostModel::new(self.topo);
        let mut finish = vec![f64::NAN; n];

        for (i, tf) in flows.iter().enumerate() {
            s.heap.push(Reverse(Ev {
                t: tf.start.max(0.0),
                kind: EV_ARRIVAL,
                flow: i as u32,
                epoch: 0,
            }));
        }
        let fsched = self.opts.faults.as_ref().filter(|f| !f.is_empty());
        if let Some(fs) = fsched {
            for (i, fe) in fs.events.iter().enumerate() {
                s.heap.push(Reverse(Ev {
                    t: fe.t.max(0.0),
                    kind: EV_FAULT,
                    flow: i as u32,
                    epoch: 0,
                }));
            }
        }
        let mut faults_due: Vec<u32> = Vec::new();
        let mut retry_due: Vec<u32> = Vec::new();
        let mut faulted: Vec<usize> = Vec::new();
        let mut failed: Vec<u32> = Vec::new();
        let mut failed_flows = 0usize;
        let mut retry_budgets =
            self.opts.policies.as_ref().map(|p| p.retry_budgets());

        let mut n_done = 0usize;

        while n_done < n {
            let now = match s.heap.peek() {
                Some(&Reverse(ev)) => ev.t,
                None => panic!("deadlock in DES: {} flows stalled", n - n_done),
            };
            assert!(now.is_finite(), "deadlock in DES");
            // batch every event at this exact time: completions are applied
            // before arrivals, mirroring the oracle loop structure
            s.completions.clear();
            s.arrivals.clear();
            faults_due.clear();
            retry_due.clear();
            faulted.clear();
            while let Some(&Reverse(ev)) = s.heap.peek() {
                if ev.t != now {
                    break;
                }
                s.heap.pop();
                let fi = ev.flow as usize;
                match ev.kind {
                    EV_COMPLETION => {
                        // stale completion events are invalidated by
                        // epoch bumps
                        if !s.st.done[fi]
                            && s.st.active[fi]
                            && ev.epoch == s.st.epoch[fi]
                        {
                            s.completions.push(fi);
                        }
                    }
                    EV_ARRIVAL => {
                        if !s.st.done[fi] && !s.st.active[fi] {
                            s.arrivals.push(fi);
                        }
                    }
                    EV_FAULT => faults_due.push(ev.flow),
                    EV_RETRY => {
                        if !s.st.done[fi]
                            && !s.st.active[fi]
                            && ev.epoch == s.st.epoch[fi]
                        {
                            retry_due.push(ev.flow);
                        }
                    }
                    _ => unreachable!("unexpected event kind in flat run"),
                }
            }
            if !faults_due.is_empty() || !retry_due.is_empty() {
                let fs = fsched.expect("fault events imply a schedule");
                let DesScratch { d, map, st, heap, completions, arrivals, .. } =
                    s;
                self.fault_tick(
                    fs, &faults_due, &retry_due, d, map, st, heap, now,
                    completions, arrivals, &mut faulted, &mut failed,
                    &mut |fi| flows[fi].rf.clone(), &[], &mut retry_budgets,
                );
                for &fu in &failed {
                    finish[fu as usize] = f64::NAN;
                    n_done += 1;
                    failed_flows += 1;
                }
                failed.clear();
            }
            if s.completions.is_empty()
                && s.arrivals.is_empty()
                && faulted.is_empty()
            {
                continue;
            }

            // completion hook: record the per-flow result row (bulk
            // completion + zero-load latency + entry queueing delay)
            for &fi in &s.completions {
                s.st.complete(&s.d, fi);
                n_done += 1;
                let tf = &flows[fi];
                finish[fi] = now
                    + cm.msg_latency(&tf.rf.path, tf.rf.flow.bytes,
                        tf.rf.flow.buf)
                    + if s.st.queue_penalty[fi].is_nan() { 0.0 }
                      else { s.st.queue_penalty[fi] };
            }
            for &fi in &s.arrivals {
                s.st.arrive(&s.d, fi, now);
            }
            self.solve_batch(
                &s.d, &mut s.st, &mut s.cscratch, &mut s.par_cscratch,
                &mut s.par_pool, &mut s.heap, now, &s.completions,
                &s.arrivals, &faulted, false,
            );
        }
        // f64::max ignores NaN, so failed flows never set the makespan
        let makespan = finish.iter().cloned().fold(0.0, f64::max);
        DesResult {
            finish,
            makespan,
            contributors: s.st.contributor_count(),
            victims: s.st.victim_count(),
            solve_batches: s.st.batches,
            components_solved: s.st.components,
            fastpath_components: s.st.fastpath,
            failed_flows,
        }
    }

    /// Execute a dependency-released workload (see
    /// [`DagWorkload`]) with the **incremental** solver.
    ///
    /// The event heap gains two dynamic event sources: a flow's bulk
    /// completion schedules its DAG node's completion after the
    /// latency/queue tail, and a node completion releases its dependents
    /// — transfers become arrivals at the release instant (so a round's
    /// completion triggers the next round's arrivals without a full
    /// re-solve), compute intervals schedule their own completion.
    /// Everything else — component walk, lazy byte sync, queueing delay,
    /// max-min, congestion classification — is the arithmetic of
    /// [`DesSim::run`].
    /// Legacy name for [`DesSession::dag`] over a throwaway scratch.
    #[doc(hidden)]
    pub fn run_dag(&self, wl: &DagWorkload) -> DagResult {
        self.run_dag_impl(wl, false, &mut DesScratch::default())
    }

    /// [`DesSim::run_dag`] over a caller-owned [`DesScratch`]: identical
    /// results, no per-call arena allocation — the hot path for `World`
    /// supersteps and campaign scenarios pricing thousands of step DAGs.
    /// Legacy name for [`DesSession::dag`].
    #[doc(hidden)]
    pub fn run_dag_with(&self, wl: &DagWorkload, s: &mut DesScratch)
        -> DagResult {
        self.run_dag_impl(wl, false, s)
    }

    /// Oracle variant of [`DesSim::run_dag`]: identical dependency
    /// semantics, but every event re-solves the *whole* active flow set
    /// (no component walk, no rate reuse) — the closed-loop analogue of
    /// [`DesSim::run_oracle`], swept against the incremental solver by
    /// `tests/des_equivalence.rs`.
    pub fn run_dag_oracle(&self, wl: &DagWorkload) -> DagResult {
        self.run_dag_impl(wl, true, &mut DesScratch::default())
    }

    fn run_dag_impl(
        &self,
        wl: &DagWorkload,
        full_resolve: bool,
        s: &mut DesScratch,
    ) -> DagResult {
        // pre-execution verifier (fabric::analysis): reject cyclic /
        // forward-dep / self-flow workloads with a structured report
        // before any solve state is touched. Debug builds only — the
        // pass is O(nodes + edges) but release campaigns re-run known
        // workload shapes millions of times.
        #[cfg(debug_assertions)]
        super::analysis::debug_check_dag(wl);
        s.reset();
        s.map.ensure(self.topo.link_universe());
        let n_nodes = wl.nodes.len();
        if n_nodes == 0 {
            return DagResult {
                node_finish: Vec::new(),
                makespan: 0.0,
                contributors: 0,
                victims: 0,
                solve_batches: 0,
                components_solved: 0,
                fastpath_components: 0,
                failed_flows: 0,
                aborted_nodes: 0,
            };
        }
        // ---- transfer nodes -> dense flow set (no RoutedFlow clones:
        // the dense representation and the latency tail read `wl`) ----
        s.node_flow.resize(n_nodes, u32::MAX); // node idx -> flow idx
        for (ni, node) in wl.nodes.iter().enumerate() {
            if let DagKind::Xfer(rf) = &node.kind {
                s.node_flow[ni] = s.flow_node.len() as u32;
                s.flow_node.push(ni as u32);
                self.push_flow(&mut s.d, &mut s.map, rf, None);
                s.st.push_flow(rf.flow.bytes as f64);
            }
        }
        s.st.grow_links(s.d.cap.len());
        let cm = super::rounds::CostModel::new(self.topo);

        // ---- DAG bookkeeping (scratch-resident; `succs` keeps inner
        // vector capacity across runs) ----
        if s.succs.len() < n_nodes {
            s.succs.resize_with(n_nodes, Vec::new);
        }
        s.deps_left.resize(n_nodes, 0);
        s.node_done.resize(n_nodes, false);
        for (ni, node) in wl.nodes.iter().enumerate() {
            s.deps_left[ni] = node.deps.len() as u32;
            for &dep in &node.deps {
                s.succs[dep as usize].push(ni as u32);
            }
        }
        let mut node_finish = vec![f64::NAN; n_nodes];
        let mut nodes_done = 0usize;

        for (ni, node) in wl.nodes.iter().enumerate() {
            if node.deps.is_empty() {
                let t0 = node.start.max(0.0);
                match &node.kind {
                    DagKind::Xfer(_) => s.heap.push(Reverse(Ev {
                        t: t0,
                        kind: EV_ARRIVAL,
                        flow: s.node_flow[ni],
                        epoch: 0,
                    })),
                    DagKind::Compute(dt) => s.heap.push(Reverse(Ev {
                        t: t0 + dt.max(0.0),
                        kind: EV_NODE,
                        flow: ni as u32,
                        epoch: 0,
                    })),
                }
            }
        }

        let fsched = self.opts.faults.as_ref().filter(|f| !f.is_empty());
        if let Some(fs) = fsched {
            for (i, fe) in fs.events.iter().enumerate() {
                s.heap.push(Reverse(Ev {
                    t: fe.t.max(0.0),
                    kind: EV_FAULT,
                    flow: i as u32,
                    epoch: 0,
                }));
            }
        }
        let mut faults_due: Vec<u32> = Vec::new();
        let mut retry_due: Vec<u32> = Vec::new();
        let mut faulted: Vec<usize> = Vec::new();
        let mut failed: Vec<u32> = Vec::new();
        let mut failed_flows = 0usize;
        let mut retry_budgets =
            self.opts.policies.as_ref().map(|p| p.retry_budgets());

        let mut finished_nodes: Vec<u32> = Vec::new();

        while nodes_done < n_nodes {
            let now = match s.heap.peek() {
                Some(&Reverse(ev)) => ev.t,
                // a failed flow's dependents never release: once the
                // heap drains, the rest of the DAG is aborted
                None if failed_flows > 0 => break,
                None => panic!(
                    "deadlock in closed-loop DES: {} of {n_nodes} nodes \
                     never released",
                    n_nodes - nodes_done
                ),
            };
            assert!(now.is_finite(), "deadlock in closed-loop DES");
            s.completions.clear();
            s.arrivals.clear();
            faults_due.clear();
            retry_due.clear();
            faulted.clear();
            finished_nodes.clear();
            while let Some(&Reverse(ev)) = s.heap.peek() {
                if ev.t != now {
                    break;
                }
                s.heap.pop();
                let fi = ev.flow as usize;
                match ev.kind {
                    EV_COMPLETION => {
                        if !s.st.done[fi]
                            && s.st.active[fi]
                            && ev.epoch == s.st.epoch[fi]
                        {
                            s.completions.push(fi);
                        }
                    }
                    EV_ARRIVAL => {
                        if !s.st.done[fi] && !s.st.active[fi] {
                            s.arrivals.push(fi);
                        }
                    }
                    EV_FAULT => faults_due.push(ev.flow),
                    EV_RETRY => {
                        if !s.st.done[fi]
                            && !s.st.active[fi]
                            && ev.epoch == s.st.epoch[fi]
                        {
                            retry_due.push(ev.flow);
                        }
                    }
                    // EV_NODE: `flow` carries the DAG node id
                    _ => finished_nodes.push(ev.flow),
                }
            }

            // ---- fault timeline: capacity changes + policy sweep,
            // before completions/arrivals (tie-break contract) ----
            if !faults_due.is_empty() || !retry_due.is_empty() {
                let fs = fsched.expect("fault events imply a schedule");
                let DesScratch {
                    d, map, st, heap, completions, arrivals, flow_node, ..
                } = s;
                let mut rf_of = |fi: usize| {
                    match &wl.nodes[flow_node[fi] as usize].kind {
                        DagKind::Xfer(rf) => rf.clone(),
                        DagKind::Compute(_) => {
                            unreachable!("flow slot maps to a transfer node")
                        }
                    }
                };
                self.fault_tick(
                    fs, &faults_due, &retry_due, d, map, st, heap, now,
                    completions, arrivals, &mut faulted, &mut failed,
                    &mut rf_of, &[], &mut retry_budgets,
                );
                failed_flows += failed.len();
                failed.clear();
            }

            // ---- flow completions (the closed-loop completion hook):
            // the bulk leaves the fabric now; the DAG node completes
            // after the latency/queue tail ----
            for &fi in &s.completions {
                s.st.complete(&s.d, fi);
                let ni = s.flow_node[fi] as usize;
                let lat = match &wl.nodes[ni].kind {
                    DagKind::Xfer(rf) => cm.msg_latency(
                        &rf.path,
                        rf.flow.bytes,
                        rf.flow.buf,
                    ),
                    DagKind::Compute(_) => {
                        unreachable!("flow slot maps to a transfer node")
                    }
                };
                let tail = lat
                    + if s.st.queue_penalty[fi].is_nan() {
                        0.0
                    } else {
                        s.st.queue_penalty[fi]
                    };
                s.heap.push(Reverse(Ev {
                    t: now + tail,
                    kind: EV_NODE,
                    flow: ni as u32,
                    epoch: 0,
                }));
            }

            // ---- node completions: release dependents. Zero-length
            // compute chains collapse within the same instant (the list
            // grows while we walk it). ----
            let mut k = 0;
            while k < finished_nodes.len() {
                let ni = finished_nodes[k] as usize;
                k += 1;
                debug_assert!(!s.node_done[ni], "node {ni} finished twice");
                s.node_done[ni] = true;
                node_finish[ni] = now;
                nodes_done += 1;
                for &su in &s.succs[ni] {
                    let su = su as usize;
                    s.deps_left[su] -= 1;
                    if s.deps_left[su] > 0 {
                        continue;
                    }
                    let rel = wl.nodes[su].start.max(now);
                    match &wl.nodes[su].kind {
                        DagKind::Xfer(_) => {
                            let fi = s.node_flow[su];
                            if rel <= now {
                                s.arrivals.push(fi as usize);
                            } else {
                                s.heap.push(Reverse(Ev {
                                    t: rel,
                                    kind: EV_ARRIVAL,
                                    flow: fi,
                                    epoch: 0,
                                }));
                            }
                        }
                        DagKind::Compute(dt) => {
                            let t_fin = rel + dt.max(0.0);
                            if t_fin <= now {
                                finished_nodes.push(su as u32);
                            } else {
                                s.heap.push(Reverse(Ev {
                                    t: t_fin,
                                    kind: EV_NODE,
                                    flow: su as u32,
                                    epoch: 0,
                                }));
                            }
                        }
                    }
                }
            }

            for &fi in &s.arrivals {
                s.st.arrive(&s.d, fi, now);
            }
            if s.completions.is_empty()
                && s.arrivals.is_empty()
                && faulted.is_empty()
            {
                continue; // pure node bookkeeping: no rate change
            }
            self.solve_batch(
                &s.d, &mut s.st, &mut s.cscratch, &mut s.par_cscratch,
                &mut s.par_pool, &mut s.heap, now, &s.completions,
                &s.arrivals, &faulted, full_resolve,
            );
        }
        // f64::max ignores NaN: aborted nodes never set the makespan
        let makespan = node_finish.iter().cloned().fold(0.0, f64::max);
        DagResult {
            node_finish,
            makespan,
            contributors: s.st.contributor_count(),
            victims: s.st.victim_count(),
            solve_batches: s.st.batches,
            components_solved: s.st.components,
            fastpath_components: s.st.fastpath,
            failed_flows,
            aborted_nodes: n_nodes - nodes_done,
        }
    }

    /// Execute a round-structured closed-loop workload **streamed**: the
    /// windowed executor for Fig 14-scale collectives (2,048+ endpoints)
    /// whose fully materialized round DAGs are O(P^2) nodes.
    ///
    /// Rounds are pulled from `src` lazily and retired once complete, so
    /// the peak live node count is bounded by the workload's dependency
    /// skew (how far fast endpoint chains run ahead of slow ones), not
    /// by `rounds x P`. The materialization window is driven by
    /// releases: the moment any node of round `k` is released, round
    /// `k+1` is materialized — a dependent can therefore never have its
    /// releasing completion arrive before the dependent exists, as long
    /// as every node's dependencies live in the previous round (true for
    /// the ring / pairwise / doubling / binomial generators, whose
    /// sources are touched every round). Workloads that violate that
    /// (a key silent for many rounds, then sending) still complete, but
    /// such nodes are released at materialization time instead of their
    /// true dependency release; [`StreamResult::late_releases`] counts
    /// them, and it is zero exactly when the streamed execution is
    /// equivalent (to solver fp noise) to `run_dag` on the fully
    /// materialized DAG — asserted by `tests/des_equivalence.rs`.
    ///
    /// One further precondition on that equivalence (NOT tracked by
    /// `late_releases`): the workload must use a single [`super::BufLoc`]
    /// per NIC endpoint link. NIC-eff capacity caps are applied as flows
    /// materialize, so a mixed-buffer source whose slower buffer type
    /// appears late would see earlier rounds priced against the not-yet-
    /// tightened cap, while `run_dag` caps from t=0. Every current
    /// caller (`coll::stream_rounds`, the workload-level generators)
    /// streams a uniform buffer class, where the caps are identical from
    /// the first solve.
    ///
    /// Frontier semantics are [`super::workload::DagBuilder`]'s: within
    /// a round every message sees the pre-round frontier; a message
    /// depends on every previous-round node touching its *source* key,
    /// and both endpoints' frontiers gain the node when the round
    /// commits. Each node additionally honours its absolute release
    /// floor ([`StreamNode`]'s `start` — per-rank clock floors for
    /// `World` superstep flushes): release = max(floor, dependency
    /// finishes). Completed flow slots are recycled (dense link/flow
    /// state reuse), so fabric memory is bounded by peak *concurrency*,
    /// not total flow count; and retirement is per-node-refcounted via
    /// frontier collapse ([`FrontierEntry`]) — a key touched once and
    /// never again does not pin its round, or any later round, live.
    pub fn run_stream(&self, src: &mut dyn RoundSource) -> StreamResult {
        self.run_stream_with(src, &mut DesScratch::default())
    }

    /// [`DesSim::run_stream`] over a caller-owned [`DesScratch`]:
    /// identical results, no per-call arena allocation. Legacy name for
    /// [`DesSession::stream`].
    #[doc(hidden)]
    pub fn run_stream_with(
        &self,
        src: &mut dyn RoundSource,
        scratch: &mut DesScratch,
    ) -> StreamResult {
        self.stream_sink_impl(src, scratch, |_, _| {})
    }

    /// [`DesSim::run_stream_with`] plus a per-node completion sink:
    /// `on_finish(id, t)` fires once per node with its global
    /// materialization-order id (0-based over non-empty rounds, in
    /// round/source order) and its absolute finish time. This is how
    /// `World`'s streamed superstep flush advances participant clocks
    /// without the executor ever holding an O(total nodes) result.
    /// Legacy name for [`DesSession::stream_sink`].
    #[doc(hidden)]
    pub fn run_stream_sink(
        &self,
        src: &mut dyn RoundSource,
        scratch: &mut DesScratch,
        on_finish: impl FnMut(u32, f64),
    ) -> StreamResult {
        self.stream_sink_impl(src, scratch, on_finish)
    }

    /// [`DesSim::stream_outcome_impl`] filtered down to the legacy
    /// finish-only sink: behind [`DesSession::stream`] /
    /// [`DesSession::stream_sink`] and the `run_stream*` wrappers.
    fn stream_sink_impl(
        &self,
        src: &mut dyn RoundSource,
        scratch: &mut DesScratch,
        mut on_finish: impl FnMut(u32, f64),
    ) -> StreamResult {
        self.stream_outcome_impl(src, scratch, |id, t, o| {
            if let FlowOutcome::Finished = o {
                on_finish(id, t);
            }
        })
    }

    /// Implementation behind [`DesSession::stream_outcomes`] (and,
    /// filtered, every other streaming entry point): the windowed
    /// streaming executor, including the [`DesOpts::policies`]
    /// degradation layer (deadline abandonment, hedge spawns, retry
    /// budgets) and the [`DesOpts::faults`] timeline.
    fn stream_outcome_impl(
        &self,
        src: &mut dyn RoundSource,
        scratch: &mut DesScratch,
        mut on_event: impl FnMut(u32, f64, FlowOutcome),
    ) -> StreamResult {
        scratch.reset();
        scratch.map.ensure(self.topo.link_universe());
        let fsched = self.opts.faults.as_ref().filter(|f| !f.is_empty());
        if let Some(fs) = fsched {
            for (i, fe) in fs.events.iter().enumerate() {
                scratch.heap.push(Reverse(Ev {
                    t: fe.t.max(0.0),
                    kind: EV_FAULT,
                    flow: i as u32,
                    epoch: 0,
                }));
            }
        }
        let cm = super::rounds::CostModel::new(self.topo);
        let mut ex = StreamExec {
            sim: self,
            s: scratch,
            base: 0,
            round_base: 0,
            materialized_rounds: 0,
            exhausted: false,
            nodes_done: 0,
            total_nodes: 0,
            peak_live: 0,
            late_releases: 0,
            rounds: 0,
            round_ev_pending: false,
        };
        let mut relwork: Vec<u32> = Vec::new();

        // ---- bootstrap: round 0 plus the cascade of rounds reachable
        // through dependency-free nodes, all released at their floors.
        // A time-throttled source (next_round_not_before > 0) defers
        // instead: its first round materializes off an EV_ROUND wake-up ----
        if let Some(t) = ex.ensure_rounds(src, 1, 0.0, &mut relwork) {
            ex.round_ev_pending = true;
            ex.s.heap.push(Reverse(Ev {
                t,
                kind: EV_ROUND,
                flow: u32::MAX,
                epoch: 0,
            }));
        }
        while let Some(rid) = relwork.pop() {
            let round = ex.node(rid).round;
            if let Some(t) = ex.ensure_rounds(src, round + 2, 0.0, &mut relwork)
            {
                if !ex.round_ev_pending {
                    ex.round_ev_pending = true;
                    ex.s.heap.push(Reverse(Ev {
                        t,
                        kind: EV_ROUND,
                        flow: u32::MAX,
                        epoch: 0,
                    }));
                }
            }
            let rel = ex.node(rid).release;
            match ex.node(rid).kind {
                StreamKind::Xfer(slot) => ex.s.heap.push(Reverse(Ev {
                    t: rel,
                    kind: EV_ARRIVAL,
                    flow: slot,
                    epoch: 0,
                })),
                StreamKind::Compute(dt) => ex.s.heap.push(Reverse(Ev {
                    t: rel + dt,
                    kind: EV_NODE,
                    flow: rid,
                    epoch: 0,
                })),
            }
        }

        let mut finished_nodes: Vec<u32> = Vec::new();
        let mut freed: Vec<u32> = Vec::new();
        let mut faults_due: Vec<u32> = Vec::new();
        let mut retry_due: Vec<u32> = Vec::new();
        let mut faulted: Vec<usize> = Vec::new();
        let mut failed: Vec<u32> = Vec::new();
        let mut deadline_due: Vec<u32> = Vec::new();
        let mut hedge_due: Vec<u32> = Vec::new();
        let mut cancelled: Vec<usize> = Vec::new();
        let mut failed_flows = 0usize;
        let mut abandoned_flows = 0usize;
        let mut hedged_flows = 0usize;
        let mut retry_budgets =
            self.opts.policies.as_ref().map(|p| p.retry_budgets());
        let mut makespan = 0.0f64;

        while ex.nodes_done < ex.total_nodes || ex.round_ev_pending {
            let now = match ex.s.heap.peek() {
                Some(&Reverse(ev)) => ev.t,
                // a failed flow stalls its node (and dependents) for
                // good: once the heap drains, the remainder is aborted
                None if failed_flows > 0 => break,
                None => panic!(
                    "deadlock in streaming DES: {} of {} live nodes never \
                     released",
                    ex.total_nodes - ex.nodes_done,
                    ex.total_nodes
                ),
            };
            assert!(now.is_finite(), "deadlock in streaming DES");
            ex.s.completions.clear();
            ex.s.arrivals.clear();
            faults_due.clear();
            retry_due.clear();
            faulted.clear();
            deadline_due.clear();
            hedge_due.clear();
            cancelled.clear();
            finished_nodes.clear();
            freed.clear();
            let mut rounds_due = false;
            while let Some(&Reverse(ev)) = ex.s.heap.peek() {
                if ev.t != now {
                    break;
                }
                ex.s.heap.pop();
                let fi = ev.flow as usize;
                match ev.kind {
                    EV_COMPLETION => {
                        if !ex.s.st.done[fi]
                            && ex.s.st.active[fi]
                            && ev.epoch == ex.s.st.epoch[fi]
                        {
                            ex.s.completions.push(fi);
                        }
                    }
                    EV_ARRIVAL => {
                        if !ex.s.st.done[fi] && !ex.s.st.active[fi] {
                            ex.s.arrivals.push(fi);
                        }
                    }
                    EV_ROUND => rounds_due = true,
                    EV_FAULT => faults_due.push(ev.flow),
                    EV_RETRY => {
                        if !ex.s.st.done[fi]
                            && !ex.s.st.active[fi]
                            && ev.epoch == ex.s.st.epoch[fi]
                        {
                            retry_due.push(ev.flow);
                        }
                    }
                    // deadline/hedge timers validate against the node id
                    // the slot carried at schedule time (`ev.epoch`):
                    // recycling gives the slot a new node and kills the
                    // event, while solve-epoch bumps (rate changes,
                    // fault detaches) leave it armed. A deadline also
                    // stays live while a hedge twin still runs even if
                    // this slot itself already failed.
                    EV_DEADLINE => {
                        if ex.s.flow_node[fi] == ev.epoch {
                            let mate = ex.s.hedge_mate[fi];
                            if !ex.s.st.done[fi]
                                || (mate != u32::MAX
                                    && !ex.s.st.done[mate as usize])
                            {
                                deadline_due.push(ev.flow);
                            }
                        }
                    }
                    EV_HEDGE => {
                        if ex.s.flow_node[fi] == ev.epoch
                            && !ex.s.st.done[fi]
                            && ex.s.st.active[fi]
                            && ex.s.hedge_mate[fi] == u32::MAX
                        {
                            hedge_due.push(ev.flow);
                        }
                    }
                    // EV_NODE: `flow` carries the global node id
                    _ => finished_nodes.push(ev.flow),
                }
            }

            // ---- fault timeline: capacity changes + policy sweep,
            // before completions/arrivals (tie-break contract) ----
            if !faults_due.is_empty() || !retry_due.is_empty() {
                let fs = fsched.expect("fault events imply a schedule");
                let DesScratch {
                    d,
                    map,
                    st,
                    heap,
                    completions,
                    arrivals,
                    flow_rf,
                    flow_class,
                    ..
                } = &mut *ex.s;
                let mut rf_of = |fi: usize| flow_rf[fi].clone();
                self.fault_tick(
                    fs, &faults_due, &retry_due, d, map, st, heap, now,
                    completions, arrivals, &mut faulted, &mut failed,
                    &mut rf_of, flow_class, &mut retry_budgets,
                );
                // a failed flow only fails its *request* once no hedge
                // twin is still in flight (the twin may yet complete, or
                // fail later and notify then — `fail` sinks must be
                // idempotent: both twins can fail in one sweep)
                for &fu in &failed {
                    let fi = fu as usize;
                    let mate = ex.s.hedge_mate[fi];
                    if mate == u32::MAX || ex.s.st.done[mate as usize] {
                        on_event(
                            ex.s.flow_node[fi],
                            now,
                            FlowOutcome::Failed,
                        );
                    }
                }
                failed_flows += failed.len();
                failed.clear();
            }

            // ---- deferred rounds whose wake-up is due: materialize every
            // round the source allows at `now`, release the new
            // dependency-free nodes at their floors (floors >= the window
            // start == now for throttled sources, so nothing is late), and
            // re-defer the remainder ----
            if rounds_due {
                ex.round_ev_pending = false;
                if let Some(t) =
                    ex.ensure_rounds(src, u32::MAX, now, &mut relwork)
                {
                    ex.round_ev_pending = true;
                    ex.s.heap.push(Reverse(Ev {
                        t,
                        kind: EV_ROUND,
                        flow: u32::MAX,
                        epoch: 0,
                    }));
                }
                while let Some(rid) = relwork.pop() {
                    let rel = ex.node(rid).release;
                    match ex.node(rid).kind {
                        StreamKind::Xfer(slot) => {
                            if rel <= now {
                                ex.s.arrivals.push(slot as usize);
                            } else {
                                let epoch = ex.s.st.epoch[slot as usize];
                                ex.s.heap.push(Reverse(Ev {
                                    t: rel,
                                    kind: EV_ARRIVAL,
                                    flow: slot,
                                    epoch,
                                }));
                            }
                        }
                        StreamKind::Compute(dt) => {
                            let t_fin = rel.max(now) + dt;
                            if t_fin <= now {
                                finished_nodes.push(rid);
                            } else {
                                ex.s.heap.push(Reverse(Ev {
                                    t: t_fin,
                                    kind: EV_NODE,
                                    flow: rid,
                                    epoch: 0,
                                }));
                            }
                        }
                    }
                }
            }

            // ---- deadline sweep ([`DesOpts::policies`]): abandon every
            // due request still on the fabric — the flow (and any hedge
            // twin) detaches, freeing its bandwidth for survivors, and
            // the node retires with [`FlowOutcome::Abandoned`]; closed-
            // loop dependents, if any, release at the abandon instant. A
            // completion at this same instant wins the tie (mirroring
            // the fault sweep); a fault-failure this instant leaves the
            // sweep nothing live to abandon. ----
            for &du in &deadline_due {
                let v = du as usize;
                let mate = ex.s.hedge_mate[v];
                let twins = [
                    Some(v),
                    if mate == u32::MAX { None } else { Some(mate as usize) },
                ];
                if twins
                    .iter()
                    .flatten()
                    .any(|w| ex.s.completions.contains(w))
                {
                    continue; // completion at this instant wins
                }
                let mut any = false;
                for &w in twins.iter().flatten() {
                    if ex.s.st.done[w] {
                        continue; // failed since the event was popped
                    }
                    let on_fabric = ex.s.st.active[w];
                    if on_fabric {
                        ex.s.st.detach(&ex.s.d, w, now);
                        // survivors sharing the abandoned flow's links
                        // re-share its freed capacity: seed their
                        // components (post-detach, like the fault sweep)
                        for &l in ex.s.d.links_of(w) {
                            faulted.extend(
                                ex.s.st.link_flows[l as usize]
                                    .iter()
                                    .map(|&x| x as usize),
                            );
                        }
                    }
                    ex.s.st.done[w] = true;
                    // recycle the slot only when no stale EV_ARRIVAL can
                    // still target it (arrival events are not
                    // epoch-checked): it was on the fabric, is waiting a
                    // retry timer (EV_RETRY is epoch-checked), or its
                    // arrival was already popped this very instant. A
                    // never-released flow's slot leaks instead —
                    // harmless, like a failed flow's.
                    if on_fabric
                        || ex.s.st.retry[w] > 0
                        || ex.s.arrivals.contains(&w)
                    {
                        freed.push(w as u32);
                    }
                    any = true;
                }
                if !any {
                    continue;
                }
                ex.s.hedge_mate[v] = u32::MAX;
                if mate != u32::MAX {
                    ex.s.hedge_mate[mate as usize] = u32::MAX;
                }
                let id = ex.s.flow_node[v];
                abandoned_flows += 1;
                makespan = makespan.max(now);
                let succs = ex.finish_node(id, now);
                on_event(id, now, FlowOutcome::Abandoned);
                for su in succs {
                    let sn = ex.node_mut(su);
                    sn.deps_left -= 1;
                    sn.release = sn.release.max(now);
                    if sn.deps_left == 0 {
                        relwork.push(su);
                    }
                }
            }

            // ---- hedge spawns ([`DesOpts::policies`]): a due request
            // still in flight gets a twin on a link-disjoint minimal
            // route (when one is up). The twin restarts the full
            // transfer and shares the primary's node: whichever twin
            // completes first finishes the request, the loser is
            // cancelled in the completions block below. ----
            for &hu in &hedge_due {
                let fi = hu as usize;
                if ex.s.st.done[fi]
                    || !ex.s.st.active[fi]
                    || ex.s.hedge_mate[fi] != u32::MAX
                    || ex.s.completions.contains(&fi)
                {
                    continue; // faulted / finished since the pop
                }
                let rf0 = ex.s.flow_rf[fi].clone();
                let path = match self.hedge_path(&ex.s.d, &rf0) {
                    Some(p) => p,
                    None => continue, // no disjoint live route: skip
                };
                let id = ex.s.flow_node[fi];
                let class = ex.s.flow_class[fi];
                let rf = RoutedFlow { flow: rf0.flow, path };
                let bytes = rf.flow.bytes as f64;
                let slot = if let Some(fs) = ex.s.free_slots.pop() {
                    let fs = fs as usize;
                    self.push_flow(&mut ex.s.d, &mut ex.s.map, &rf, Some(fs));
                    ex.s.st.recycle_flow(fs, bytes);
                    ex.s.flow_node[fs] = id;
                    ex.s.flow_rf[fs] = rf;
                    ex.s.flow_class[fs] = class;
                    ex.s.hedge_mate[fs] = hu;
                    fs
                } else {
                    let fs =
                        self.push_flow(&mut ex.s.d, &mut ex.s.map, &rf, None);
                    ex.s.st.push_flow(bytes);
                    ex.s.flow_node.push(id);
                    ex.s.flow_rf.push(rf);
                    ex.s.flow_class.push(class);
                    ex.s.hedge_mate.push(hu);
                    fs
                };
                ex.s.st.grow_links(ex.s.d.cap.len());
                ex.s.hedge_mate[fi] = slot as u32;
                ex.s.arrivals.push(slot);
                hedged_flows += 1;
                on_event(id, now, FlowOutcome::Hedged);
            }

            // ---- flow completions: bulk leaves the fabric now, node
            // completes after the latency/queue tail; the slot is
            // recycled after this batch's solve. First-completion-wins
            // for hedged pairs: the winner cancels its twin. ----
            for i in 0..ex.s.completions.len() {
                let fi = ex.s.completions[i];
                if ex.s.st.done[fi] {
                    // hedge loser: its twin completed earlier this batch
                    cancelled.push(fi);
                    continue;
                }
                ex.s.st.complete(&ex.s.d, fi);
                let rf = &ex.s.flow_rf[fi];
                let tail = cm.msg_latency(&rf.path, rf.flow.bytes, rf.flow.buf)
                    + if ex.s.st.queue_penalty[fi].is_nan() {
                        0.0
                    } else {
                        ex.s.st.queue_penalty[fi]
                    };
                ex.s.heap.push(Reverse(Ev {
                    t: now + tail,
                    kind: EV_NODE,
                    flow: ex.s.flow_node[fi],
                    epoch: 0,
                }));
                freed.push(fi as u32);
                let mate = ex.s.hedge_mate[fi];
                if mate != u32::MAX {
                    let vi = mate as usize;
                    ex.s.hedge_mate[fi] = u32::MAX;
                    ex.s.hedge_mate[vi] = u32::MAX;
                    if !ex.s.st.done[vi] {
                        if ex.s.st.active[vi] {
                            ex.s.st.detach(&ex.s.d, vi, now);
                            for &l in ex.s.d.links_of(vi) {
                                faulted.extend(
                                    ex.s.st.link_flows[l as usize]
                                        .iter()
                                        .map(|&x| x as usize),
                                );
                            }
                        }
                        ex.s.st.done[vi] = true;
                        freed.push(mate);
                    }
                }
            }
            // the batch lists feed the solver: drop hedge losers that
            // were cancelled after being popped as completions/arrivals
            // this instant (policy-armed runs only — the lists are
            // untouched otherwise)
            if self.opts.policies.is_some() {
                let DesScratch { st, completions, arrivals, .. } =
                    &mut *ex.s;
                if !cancelled.is_empty() {
                    completions.retain(|fi| !cancelled.contains(fi));
                }
                arrivals.retain(|&fi| !st.done[fi]);
            }

            // ---- node completions: release dependents, materializing
            // the next round the moment a deeper round first releases.
            // Zero-length compute chains collapse within the instant
            // (the list grows while we walk it, as in `run_dag`). The
            // drain leads the loop so releases seeded by the deadline
            // sweep above flow through even when nothing finished. ----
            let mut k = 0;
            loop {
                while let Some(rid) = relwork.pop() {
                    let round = ex.node(rid).round;
                    if let Some(t) =
                        ex.ensure_rounds(src, round + 2, now, &mut relwork)
                    {
                        if !ex.round_ev_pending {
                            ex.round_ev_pending = true;
                            ex.s.heap.push(Reverse(Ev {
                                t,
                                kind: EV_ROUND,
                                flow: u32::MAX,
                                epoch: 0,
                            }));
                        }
                    }
                    let rel = ex.node(rid).release;
                    let rel = if rel < now {
                        // dependencies all finished before this node was
                        // materialized: clamp (inexact, counted)
                        ex.late_releases += 1;
                        now
                    } else {
                        rel
                    };
                    match ex.node(rid).kind {
                        StreamKind::Xfer(slot) => {
                            if rel <= now {
                                ex.s.arrivals.push(slot as usize);
                            } else {
                                let epoch = ex.s.st.epoch[slot as usize];
                                ex.s.heap.push(Reverse(Ev {
                                    t: rel,
                                    kind: EV_ARRIVAL,
                                    flow: slot,
                                    epoch,
                                }));
                            }
                        }
                        StreamKind::Compute(dt) => {
                            let t_fin = rel + dt;
                            if t_fin <= now {
                                finished_nodes.push(rid);
                            } else {
                                ex.s.heap.push(Reverse(Ev {
                                    t: t_fin,
                                    kind: EV_NODE,
                                    flow: rid,
                                    epoch: 0,
                                }));
                            }
                        }
                    }
                }
                if k >= finished_nodes.len() {
                    break;
                }
                let id = finished_nodes[k];
                k += 1;
                makespan = makespan.max(now);
                let succs = ex.finish_node(id, now);
                on_event(id, now, FlowOutcome::Finished);
                for su in succs {
                    let sn = ex.node_mut(su);
                    sn.deps_left -= 1;
                    sn.release = sn.release.max(now);
                    if sn.deps_left == 0 {
                        relwork.push(su);
                    }
                }
            }

            for &fi in &ex.s.arrivals {
                ex.s.st.arrive(&ex.s.d, fi, now);
            }
            if !(ex.s.completions.is_empty()
                && ex.s.arrivals.is_empty()
                && faulted.is_empty())
            {
                self.solve_batch(
                    &ex.s.d, &mut ex.s.st, &mut ex.s.cscratch,
                    &mut ex.s.par_cscratch, &mut ex.s.par_pool,
                    &mut ex.s.heap, now, &ex.s.completions,
                    &ex.s.arrivals, &faulted, false,
                );
            }
            // recycle flow slots only after the solve: the component walk
            // reads the completed flows' links
            ex.s.free_slots.append(&mut freed);
            ex.retire();
        }
        StreamResult {
            makespan,
            rounds: ex.rounds,
            total_nodes: ex.total_nodes,
            peak_live_nodes: ex.peak_live,
            contributors: ex.s.st.contributor_count(),
            victims: ex.s.st.victim_count(),
            late_releases: ex.late_releases,
            solve_batches: ex.s.st.batches,
            components_solved: ex.s.st.components,
            fastpath_components: ex.s.st.fastpath,
            failed_flows,
            aborted_nodes: ex.total_nodes - ex.nodes_done,
            abandoned_flows,
            hedged_flows,
        }
    }

    /// Exact max-min (progressive filling with per-flow caps) restricted
    /// to one component, driven by the per-link active-flow index instead
    /// of whole-system scans. Same math as [`DesSim::maxmin_dense`]
    /// (`fair = rem_cap / count`), so the two solvers reach the same
    /// unique fixpoint.
    ///
    /// Fair shares are monotone non-decreasing during filling (a flow is
    /// only ever fixed at `c <=` every remaining link's fair share, and
    /// removing it raises that share: `(rem - c)/(count - 1) >=
    /// rem/count` when `c <= rem/count`), so the link heap may hold
    /// stale, smaller keys; entries are re-validated and re-inserted on
    /// pop. `slot`, `rem_cap`, `count` and `touched` are caller-owned
    /// scratch, zeroed on return.
    ///
    /// Returns `(rates, fast)` where `fast` flags that the
    /// single-bottleneck fast path serviced the component (statistics
    /// only — the rates are bit-identical either way).
    #[allow(clippy::too_many_arguments)]
    fn maxmin_component(
        &self,
        d: &Dense,
        comp: &[usize],
        link_flows: &[Vec<u32>],
        rem_cap: &mut [f64],
        count: &mut [u32],
        slot: &mut [u32],
        touched: &mut Vec<u32>,
    ) -> (Vec<f64>, bool) {
        let nc = comp.len();
        let mut rates = vec![f64::NAN; nc];
        touched.clear();
        for (idx, &fi) in comp.iter().enumerate() {
            slot[fi] = idx as u32 + 1;
            for &l in d.links_of(fi) {
                let li = l as usize;
                if count[li] == 0 {
                    touched.push(l);
                    rem_cap[li] = d.cap[li];
                }
                count[li] += 1;
            }
        }
        // ---- single-bottleneck fast path: bit-identical shortcuts for
        // the shapes that dominate real batches (EXPERIMENTS.md §Raw
        // speed). Each branch reproduces exactly what the waterfill
        // below would do on its first fixing step when that step covers
        // the whole component, so `f64` results match to the bit. ----
        if self.opts.single_bottleneck_fastpath {
            if nc == 1 {
                // lone flow: its rate is min(flow cap, tightest link) —
                // the general path's single iteration, written out
                let fi = comp[0];
                let mut fair = f64::INFINITY;
                for &l in d.links_of(fi) {
                    let v = rem_cap[l as usize].max(0.0);
                    if v < fair {
                        fair = v;
                    }
                }
                let cap = d.flow_cap[fi];
                // `cap <= fair` mirrors `flow_level <= link_level`
                rates[0] = if cap <= fair { cap } else { fair };
                for &l in touched.iter() {
                    count[l as usize] = 0;
                }
                slot[fi] = 0;
                return (rates, true);
            }
            // the lexicographic (fair, link) minimum over the touched
            // links is exactly the waterfill's first heap pop
            let mut bl = u32::MAX;
            let mut bfair = f64::INFINITY;
            for &l in touched.iter() {
                let f = rem_cap[l as usize].max(0.0) / count[l as usize] as f64;
                if f < bfair || (f == bfair && l < bl) {
                    bfair = f;
                    bl = l;
                }
            }
            if bl != u32::MAX && count[bl as usize] as usize == nc {
                // every flow crosses the binding link: the first fixing
                // step assigns all of them the equal share. Strict `>`
                // keeps cap ties on the general path (which fixes flow
                // caps first at equal levels).
                let mut min_cap = f64::INFINITY;
                for &fi in comp {
                    let c = d.flow_cap[fi];
                    if c < min_cap {
                        min_cap = c;
                    }
                }
                if min_cap > bfair {
                    for r in rates.iter_mut() {
                        *r = bfair;
                    }
                    for &l in touched.iter() {
                        count[l as usize] = 0;
                    }
                    for &fi in comp {
                        slot[fi] = 0;
                    }
                    return (rates, true);
                }
            }
        }
        let mut fixed = vec![false; nc];
        // flows sorted by issue cap: the "next flow-cap constraint" pointer
        let mut cap_order: Vec<u32> = (0..nc as u32).collect();
        cap_order.sort_unstable_by(|&a, &b| {
            d.flow_cap[comp[a as usize]]
                .total_cmp(&d.flow_cap[comp[b as usize]])
        });
        let mut cap_ptr = 0usize;
        let mut lheap: BinaryHeap<Reverse<LinkLevel>> = touched
            .iter()
            .map(|&l| {
                let li = l as usize;
                Reverse(LinkLevel {
                    fair: rem_cap[li].max(0.0) / count[li] as f64,
                    link: l,
                })
            })
            .collect();
        let mut n_fixed = 0usize;
        while n_fixed < nc {
            // next binding link constraint (lazy re-validation)
            let link_cand = loop {
                match lheap.peek() {
                    None => break None,
                    Some(&Reverse(LinkLevel { fair, link })) => {
                        let li = link as usize;
                        if count[li] == 0 {
                            lheap.pop();
                            continue;
                        }
                        let cur = rem_cap[li].max(0.0) / count[li] as f64;
                        if cur > fair {
                            lheap.pop();
                            lheap.push(Reverse(LinkLevel { fair: cur, link }));
                            continue;
                        }
                        break Some((link, cur));
                    }
                }
            };
            while cap_ptr < nc && fixed[cap_order[cap_ptr] as usize] {
                cap_ptr += 1;
            }
            let flow_cand = if cap_ptr < nc {
                let s = cap_order[cap_ptr] as usize;
                Some((s, d.flow_cap[comp[s]]))
            } else {
                None
            };
            let link_level = link_cand.map_or(f64::INFINITY, |(_, f)| f);
            let flow_level = flow_cand.map_or(f64::INFINITY, |(_, f)| f);
            if flow_level <= link_level {
                let (s, c) =
                    flow_cand.expect("unfixed flow implies a cap constraint");
                rates[s] = c;
                fixed[s] = true;
                n_fixed += 1;
                for &l in d.links_of(comp[s]) {
                    rem_cap[l as usize] -= c;
                    count[l as usize] -= 1;
                }
            } else {
                let (l, fair) = link_cand.expect("link level was finite");
                for &fu in &link_flows[l as usize] {
                    debug_assert!(
                        slot[fu as usize] > 0,
                        "link member outside component"
                    );
                    let s = (slot[fu as usize] - 1) as usize;
                    if fixed[s] {
                        continue;
                    }
                    rates[s] = fair;
                    fixed[s] = true;
                    n_fixed += 1;
                    for &ll in d.links_of(fu as usize) {
                        rem_cap[ll as usize] -= fair;
                        count[ll as usize] -= 1;
                    }
                }
                count[l as usize] = 0; // saturated / dead
            }
        }
        for &l in touched.iter() {
            count[l as usize] = 0;
        }
        for &fi in comp {
            slot[fi] = 0;
        }
        (rates, false)
    }
}

/// Builder returned by [`DesSim::session`]: one entry point for every
/// execution mode of the simulator, over a caller-owned scratch arena.
/// `.opts(custom)` overrides the simulator's [`DesOpts`] for this run
/// only (the `DesSim` itself is untouched); the terminal methods
/// (`solve` / `simultaneous` / `dag` / `stream` / `stream_sink`) consume
/// the session and run the same implementations the legacy
/// `DesSim::run*` names delegate to, so results are bit-identical by
/// construction (and proven so by `tests/session_api.rs`).
pub struct DesSession<'a, 's, 't> {
    sim: &'a DesSim<'t>,
    scratch: &'s mut DesScratch,
    opts: Option<DesOpts>,
}

impl<'a, 's, 't> DesSession<'a, 's, 't> {
    /// Override the simulator's [`DesOpts`] for this session only.
    pub fn opts(mut self, opts: DesOpts) -> Self {
        self.opts = Some(opts);
        self
    }

    /// Install a mid-run fault timeline for this session only
    /// (composes with [`DesSession::opts`] in either order).
    pub fn faults(mut self, schedule: super::faults::FaultSchedule) -> Self {
        let mut o = self
            .opts
            .take()
            .unwrap_or_else(|| self.sim.opts.clone());
        o.faults = Some(schedule);
        self.opts = Some(o);
        self
    }

    /// Arm a [`super::degrade::ServicePolicy`] for this session only
    /// (composes with [`DesSession::opts`] / [`DesSession::faults`] in
    /// any order). Enforced by the streaming executor; batch executors
    /// honor only the class-0 retry budget.
    pub fn policies(
        mut self,
        policy: super::degrade::ServicePolicy,
    ) -> Self {
        let mut o = self
            .opts
            .take()
            .unwrap_or_else(|| self.sim.opts.clone());
        o.policies = Some(policy);
        self.opts = Some(o);
        self
    }

    /// The simulator this session runs on: the borrowed one, or a
    /// same-topology twin carrying the session's [`DesOpts`] override.
    fn effective(&self) -> DesSim<'t> {
        DesSim {
            topo: self.sim.topo,
            opts: self
                .opts
                .clone()
                .unwrap_or_else(|| self.sim.opts.clone()),
        }
    }

    /// Flat timed flow set — the session twin of [`DesSim::run`] /
    /// [`DesSim::run_with`].
    pub fn solve(self, flows: &[TimedFlow]) -> DesResult {
        let sim = self.effective();
        sim.solve_impl(flows, self.scratch)
    }

    /// All flows start at t=0; per-flow durations — the session twin of
    /// [`DesSim::run_simultaneous`] / [`DesSim::run_simultaneous_with`].
    pub fn simultaneous(self, flows: &[RoutedFlow]) -> FlowTimes {
        let sim = self.effective();
        sim.simultaneous_impl(flows, self.scratch)
    }

    /// Closed-loop dependency DAG — the session twin of
    /// [`DesSim::run_dag`] / [`DesSim::run_dag_with`].
    pub fn dag(self, wl: &DagWorkload) -> DagResult {
        let sim = self.effective();
        sim.run_dag_impl(wl, false, self.scratch)
    }

    /// Windowed streaming execution — the session twin of
    /// [`DesSim::run_stream`] / [`DesSim::run_stream_with`].
    pub fn stream(self, src: &mut dyn RoundSource) -> StreamResult {
        let sim = self.effective();
        sim.stream_sink_impl(src, self.scratch, |_, _| {})
    }

    /// Streaming execution with a per-node completion sink — the session
    /// twin of [`DesSim::run_stream_sink`].
    pub fn stream_sink(
        self,
        src: &mut dyn RoundSource,
        on_finish: impl FnMut(u32, f64),
    ) -> StreamResult {
        let sim = self.effective();
        sim.stream_sink_impl(src, self.scratch, on_finish)
    }

    /// Streaming execution with a full per-node outcome sink:
    /// `on_event(id, t, outcome)` fires once per terminal outcome
    /// ([`FlowOutcome::Finished`] / [`FlowOutcome::Failed`] /
    /// [`FlowOutcome::Abandoned`]) plus once per hedge spawn
    /// ([`FlowOutcome::Hedged`], non-terminal — the node still reaches a
    /// terminal outcome later). This is how the open-loop collector
    /// retires failed and abandoned requests instead of carrying them as
    /// phantom backlog.
    pub fn stream_outcomes(
        self,
        src: &mut dyn RoundSource,
        on_event: impl FnMut(u32, f64, FlowOutcome),
    ) -> StreamResult {
        let sim = self.effective();
        sim.stream_outcome_impl(src, self.scratch, on_event)
    }
}

const EV_COMPLETION: u8 = 0;
const EV_ARRIVAL: u8 = 1;
/// DAG-node completion (closed-loop runs only): `Ev::flow` carries the
/// workload node id, not a flow index.
const EV_NODE: u8 = 2;
/// Deferred-round wake-up (streaming runs with a time-throttled
/// [`RoundSource`] only): the source's next round becomes materializable
/// at `Ev::t`. `Ev::flow` is unused (`u32::MAX`); at most one is in
/// flight per run (`StreamExec::round_ev_pending`). Ordered after every
/// node completion at the same instant, which is irrelevant for
/// correctness (materialization happens after the pop loop either way)
/// but keeps the heap order stable.
const EV_ROUND: u8 = 3;
/// Mid-run fault timeline entry ([`DesOpts::faults`]): `Ev::flow`
/// carries the *index into the schedule's event list* (epoch 0). Heap
/// position within an instant is irrelevant — the batch pop collects
/// every event at `now` and [`DesSim::fault_tick`] runs before the
/// completion/arrival processing, so the fault applies first; the one
/// exception is a flow whose completion event shares the timestamp,
/// which still completes (see `fabric::faults`).
const EV_FAULT: u8 = 4;
/// Retry-backoff re-arrival ([`super::FaultPolicy::RetryBackoff`]):
/// `Ev::flow` is the flow slot, `Ev::epoch` the slot epoch at schedule
/// time. At fire time the flow re-checks its path against the live
/// capacities — still down re-arms the backoff (or fails past the
/// retry cap), healthy re-attaches as a normal arrival.
const EV_RETRY: u8 = 5;
/// Service-policy deadline ([`DesOpts::policies`], streaming runs only):
/// `Ev::flow` is the flow slot, `Ev::epoch` the *workload node id* the
/// slot carried at schedule time. Node-id validation (rather than the
/// solve-epoch used by `EV_COMPLETION`) is deliberate: commits bump the
/// slot epoch on every rate change, but a deadline must survive those
/// and only die when the slot is recycled to a new node. At fire time a
/// still-running flow is abandoned: detached from its links (freeing
/// bandwidth for survivors), its node retired with
/// [`FlowOutcome::Abandoned`]. A completion at the same instant wins.
const EV_DEADLINE: u8 = 6;
/// Service-policy hedge trigger ([`DesOpts::policies`], streaming runs
/// only): same `flow`/`epoch` encoding as `EV_DEADLINE`. At fire time a
/// still-running flow gets a duplicate spawned on a link-disjoint
/// minimal route (if one is up); first completion wins and the loser is
/// cancelled. A completion at the same instant suppresses the hedge.
const EV_HEDGE: u8 = 7;

/// Heap event for the incremental solver (min-heap through `Reverse`):
/// ordered by time, completions before arrivals at equal times.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Ev {
    t: f64,
    kind: u8,
    flow: u32,
    epoch: u32,
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&other.t)
            .then_with(|| self.kind.cmp(&other.kind))
            .then_with(|| self.flow.cmp(&other.flow))
            .then_with(|| self.epoch.cmp(&other.epoch))
    }
}

/// Lazy-heap entry for `maxmin_component`: a link's prospective fair-share
/// water level at the time it was (re)inserted.
#[derive(Debug, Clone, Copy, PartialEq)]
struct LinkLevel {
    fair: f64,
    link: u32,
}

impl Eq for LinkLevel {}

impl PartialOrd for LinkLevel {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for LinkLevel {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.fair
            .total_cmp(&other.fair)
            .then_with(|| self.link.cmp(&other.link))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::fabric::{Flow, Router};

    fn setup() -> Topology {
        Topology::new(&AuroraConfig::small(4, 4))
    }

    fn routed(topo: &Topology, flows: Vec<Flow>) -> Vec<RoutedFlow> {
        let mut r = Router::new(topo);
        flows
            .into_iter()
            .map(|f| RoutedFlow { path: r.route(&f), flow: f })
            .collect()
    }

    #[test]
    fn single_flow_rate_matches_issue_cap() {
        let t = setup();
        let sim = DesSim::new(&t, DesOpts::default());
        let bytes = 1u64 << 30;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let res = sim.run_simultaneous(&fl);
        let rate = bytes as f64 / res.makespan;
        let cap = t.cfg.rank_issue_bw_host;
        assert!((rate - cap).abs() / cap < 0.02, "rate {rate} cap {cap}");
    }

    #[test]
    fn nic_sharing_halves_rates() {
        let t = setup();
        let sim = DesSim::new(&t, DesOpts::default());
        let bytes = 1u64 << 30;
        // two ranks on the same NIC: fair share of nic_bw
        let fl = routed(
            &t,
            vec![Flow::new(0, 200, bytes), Flow::new(0, 208, bytes)],
        );
        let res = sim.run_simultaneous(&fl);
        let agg = 2.0 * bytes as f64 / res.makespan;
        assert!(agg <= t.cfg.nic_bw * 1.02, "aggregate {agg}");
        // but two ranks *do* push the NIC harder than one rank could
        assert!(agg > t.cfg.rank_issue_bw_host * 1.3);
    }

    #[test]
    fn incast_contributors_share_ejection_fairly() {
        let t = setup();
        let sim = DesSim::new(&t, DesOpts::default());
        let bytes = 64u64 << 20;
        // 8-to-1 incast onto NIC 200
        let fl = routed(
            &t,
            (0..8).map(|i| Flow::new(i * 8, 200, bytes)).collect(),
        );
        let res = sim.run_simultaneous(&fl);
        let agg = 8.0 * bytes as f64 / res.makespan;
        assert!(agg <= t.cfg.nic_bw * 1.05, "incast exceeds ejection: {agg}");
    }

    #[test]
    fn victims_protected_with_congestion_mgmt() {
        let t = setup();
        let bytes = 16u64 << 20;
        // incast from group 1 NICs onto NIC 200 + one victim 0 -> 300
        let mut flows: Vec<Flow> =
            (0..6).map(|i| Flow::new(128 + i * 8, 200, bytes)).collect();
        flows.push(Flow::new(0, 300, bytes));
        let fl = routed(&t, flows);
        let on = DesSim::new(&t, DesOpts { congestion_mgmt: true, ..DesOpts::default() })
            .run_simultaneous(&fl);
        let off = DesSim::new(&t, DesOpts { congestion_mgmt: false, ..DesOpts::default() })
            .run_simultaneous(&fl);
        let victim_on = on.per_flow[6];
        let victim_off = off.per_flow[6];
        // victim may or may not share links; congestion mgmt must never be
        // worse, and when contaminated it is strictly better
        assert!(victim_on <= victim_off * 1.01,
            "victim with mgmt {victim_on} vs without {victim_off}");
    }

    #[test]
    fn congestion_off_hurts_crossing_victims() {
        let t = setup();
        let bytes = 16u64 << 20;
        // incast flows ejecting at NIC 200 (group 0... NIC200 is in group 3
        // region), victim shares the source group links
        let mut flows: Vec<Flow> =
            (0..8).map(|i| Flow::new(i * 8, 200, bytes)).collect();
        // victim from same source switch as contributor 0, different dest
        flows.push(Flow::new(1, 210, bytes));
        let fl = routed(&t, flows);
        let off = DesSim::new(&t, DesOpts { congestion_mgmt: false, ..DesOpts::default() })
            .run_simultaneous(&fl);
        let on = DesSim::new(&t, DesOpts::default()).run_simultaneous(&fl);
        assert!(off.per_flow[8] >= on.per_flow[8],
            "victim must not be faster without congestion mgmt");
    }

    #[test]
    fn degraded_link_slows_flows() {
        let t = setup();
        let bytes = 64u64 << 20;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let healthy = DesSim::new(&t, DesOpts::default()).run_simultaneous(&fl);
        let mut degraded = BTreeMap::new();
        // half the lanes on every link of this path (§3.4 degraded mode)
        for l in &fl[0].path.links {
            degraded.insert(*l, 0.5);
        }
        let slow = DesSim::new(&t, DesOpts { degraded, ..DesOpts::default() })
            .run_simultaneous(&fl);
        assert!(slow.makespan > healthy.makespan * 1.05);
    }

    #[test]
    fn staggered_arrivals_respected() {
        let t = setup();
        let bytes = 16u64 << 20;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let sim = DesSim::new(&t, DesOpts::default());
        let timed = vec![TimedFlow { rf: fl[0].clone(), start: 1.0 }];
        let res = sim.run(&timed);
        assert!(res.finish[0] > 1.0);
    }

    fn assert_equivalent(opts: DesOpts, topo: &Topology, timed: &[TimedFlow]) {
        let sim = DesSim::new(topo, opts);
        let inc = sim.run(timed);
        let ora = sim.run_oracle(timed);
        for (i, (a, b)) in inc.finish.iter().zip(&ora.finish).enumerate() {
            let rel = (a - b).abs() / b.abs().max(1e-30);
            assert!(rel < 1e-9, "flow {i}: inc {a} vs oracle {b}");
        }
        assert_eq!(inc.contributors, ora.contributors, "contributor sets");
        assert_eq!(inc.victims, ora.victims, "victim sets");
    }

    #[test]
    fn incremental_matches_oracle_incast() {
        let t = setup();
        let fl = routed(
            &t,
            (0..8).map(|i| Flow::new(i * 8, 200, 32u64 << 20)).collect(),
        );
        let timed: Vec<TimedFlow> = fl
            .iter()
            .map(|rf| TimedFlow { rf: rf.clone(), start: 0.0 })
            .collect();
        assert_equivalent(DesOpts::default(), &t, &timed);
        assert_equivalent(
            DesOpts { congestion_mgmt: false, ..DesOpts::default() },
            &t,
            &timed,
        );
    }

    #[test]
    fn incremental_matches_oracle_staggered() {
        let t = setup();
        let fl = routed(
            &t,
            (0..12)
                .map(|i| Flow::new(i * 4, 128 + i * 2, (4u64 + i as u64) << 20))
                .collect(),
        );
        let timed: Vec<TimedFlow> = fl
            .iter()
            .enumerate()
            .map(|(i, rf)| TimedFlow {
                rf: rf.clone(),
                start: (i % 4) as f64 * 1e-3,
            })
            .collect();
        assert_equivalent(DesOpts::default(), &t, &timed);
    }

    #[test]
    fn fault_t0_degrade_matches_static_degraded_bitwise() {
        let t = setup();
        let bytes = 64u64 << 20;
        let fl = routed(
            &t,
            vec![Flow::new(0, 200, bytes), Flow::new(8, 208, bytes)],
        );
        let timed: Vec<TimedFlow> = fl
            .iter()
            .map(|rf| TimedFlow { rf: rf.clone(), start: 0.0 })
            .collect();
        let mut degraded = BTreeMap::new();
        let mut sched = FaultSchedule::new(FaultPolicy::Reroute);
        for l in &fl[0].path.links {
            degraded.insert(*l, 0.5);
            sched = sched.at(
                0.0,
                super::super::faults::FaultKind::LinkDegrade {
                    link: *l,
                    multiplier: 0.5,
                },
            );
        }
        let st = DesSim::new(&t, DesOpts { degraded, ..DesOpts::default() })
            .run(&timed);
        let dy = DesSim::new(
            &t,
            DesOpts { faults: Some(sched), ..DesOpts::default() },
        )
        .run(&timed);
        for (i, (a, b)) in st.finish.iter().zip(&dy.finish).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "flow {i}: static {a} vs t=0 fault {b}"
            );
        }
        assert_eq!(dy.failed_flows, 0);
    }

    #[test]
    fn mid_run_nic_down_abort_fails_flow() {
        use super::super::faults::FaultKind;
        let t = setup();
        let bytes = 256u64 << 20;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let healthy =
            DesSim::new(&t, DesOpts::default()).run_simultaneous(&fl);
        let sched = FaultSchedule::new(FaultPolicy::Abort)
            .at(healthy.makespan * 0.5, FaultKind::NicDown { endpoint: 0 });
        let timed = vec![TimedFlow { rf: fl[0].clone(), start: 0.0 }];
        let res = DesSim::new(
            &t,
            DesOpts { faults: Some(sched), ..DesOpts::default() },
        )
        .run(&timed);
        assert_eq!(res.failed_flows, 1);
        assert!(res.finish[0].is_nan(), "aborted flow must not finish");
        assert_eq!(res.makespan, 0.0, "NaN finishes never set the makespan");
    }

    #[test]
    fn retry_backoff_resumes_after_recovery() {
        use super::super::faults::FaultKind;
        let t = setup();
        let bytes = 256u64 << 20;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let healthy =
            DesSim::new(&t, DesOpts::default()).run_simultaneous(&fl);
        let t_down = healthy.makespan * 0.5;
        let outage = healthy.makespan * 0.2;
        // the flow's source NIC dies and comes back: the retry timer
        // (5%, then 10% of the healthy makespan) crosses the recovery
        // on its third attempt
        let sched = FaultSchedule::new(FaultPolicy::RetryBackoff {
            timeout: healthy.makespan * 0.05,
            backoff: 2.0,
            max_retries: 10,
        })
        .at(t_down, FaultKind::NicDown { endpoint: 0 })
        .at(t_down + outage, FaultKind::LinkRecover { link: LinkId::NicUp(0) })
        .at(
            t_down + outage,
            FaultKind::LinkRecover { link: LinkId::NicDown(0) },
        );
        let timed = vec![TimedFlow { rf: fl[0].clone(), start: 0.0 }];
        let res = DesSim::new(
            &t,
            DesOpts { faults: Some(sched), ..DesOpts::default() },
        )
        .run(&timed);
        assert_eq!(res.failed_flows, 0);
        assert!(res.finish[0].is_finite());
        assert!(
            res.finish[0] > healthy.makespan,
            "outage must cost time: {} vs healthy {}",
            res.finish[0],
            healthy.makespan
        );
    }

    #[test]
    fn retry_exhaustion_fails_flow() {
        use super::super::faults::FaultKind;
        let t = setup();
        let bytes = 256u64 << 20;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let healthy =
            DesSim::new(&t, DesOpts::default()).run_simultaneous(&fl);
        // NIC never recovers: both retries burn out -> failed
        let sched = FaultSchedule::new(FaultPolicy::RetryBackoff {
            timeout: healthy.makespan * 0.1,
            backoff: 2.0,
            max_retries: 2,
        })
        .at(healthy.makespan * 0.5, FaultKind::NicDown { endpoint: 0 });
        let timed = vec![TimedFlow { rf: fl[0].clone(), start: 0.0 }];
        let res = DesSim::new(
            &t,
            DesOpts { faults: Some(sched), ..DesOpts::default() },
        )
        .run(&timed);
        assert_eq!(res.failed_flows, 1);
        assert!(res.finish[0].is_nan());
    }

    #[test]
    fn reroute_survives_mid_run_global_link_down() {
        use super::super::faults::FaultKind;
        let t = setup();
        let bytes = 256u64 << 20;
        let fl = routed(&t, vec![Flow::new(0, 200, bytes)]);
        let glob = *fl[0]
            .path
            .links
            .iter()
            .find(|l| matches!(l, LinkId::Global { .. }))
            .expect("0 -> 200 crosses groups");
        let healthy =
            DesSim::new(&t, DesOpts::default()).run_simultaneous(&fl);
        let sched = FaultSchedule::new(FaultPolicy::Reroute)
            .at(healthy.makespan * 0.5, FaultKind::LinkDown { link: glob });
        let timed = vec![TimedFlow { rf: fl[0].clone(), start: 0.0 }];
        let res = DesSim::new(
            &t,
            DesOpts { faults: Some(sched), ..DesOpts::default() },
        )
        .run(&timed);
        assert_eq!(res.failed_flows, 0, "a parallel global link exists");
        assert!(res.finish[0].is_finite());
        // the alternate minimal path has the same structure and no
        // contention: the mid-run reroute is free to fp noise
        let rel = (res.finish[0] - healthy.makespan).abs()
            / healthy.makespan;
        assert!(rel < 1e-6, "reroute cost {rel}");
    }

    #[test]
    fn dag_abort_reports_aborted_dependents() {
        use super::super::faults::FaultKind;
        use super::super::workload::DagWorkload;
        let t = setup();
        let mut r = Router::new(&t);
        let bytes = 256u64 << 20;
        let fa = Flow::new(0, 200, bytes);
        let pa = r.route(&fa);
        let fb = Flow::new(200, 64, bytes);
        let pb = r.route(&fb);
        let fc = Flow::new(8, 72, bytes);
        let pc = r.route(&fc);
        let mut wl = DagWorkload::new();
        let a = wl.xfer(RoutedFlow { flow: fa, path: pa }, Vec::new());
        let _b = wl.xfer(RoutedFlow { flow: fb, path: pb }, vec![a]);
        // an independent chain elsewhere survives the abort
        let _c = wl.xfer(RoutedFlow { flow: fc, path: pc }, Vec::new());
        let healthy = DesSim::new(&t, DesOpts::default()).run_dag(&wl);
        assert_eq!(healthy.aborted_nodes, 0);
        let sched = FaultSchedule::new(FaultPolicy::Abort).at(
            healthy.node_finish[a as usize] * 0.25,
            FaultKind::NicDown { endpoint: 0 },
        );
        let res = DesSim::new(
            &t,
            DesOpts { faults: Some(sched), ..DesOpts::default() },
        )
        .run_dag(&wl);
        assert_eq!(res.failed_flows, 1, "only the first chain's head fails");
        assert_eq!(res.aborted_nodes, 2, "head + released dependent");
        assert!(res.node_finish[0].is_nan());
        assert!(res.node_finish[1].is_nan());
        assert!(res.node_finish[2].is_finite(), "independent chain runs");
        assert!(res.makespan > 0.0);
    }

    #[test]
    fn incremental_matches_oracle_disjoint_components() {
        // two flow groups in different dragonfly groups: the incremental
        // solver must keep them in independent components
        let t = setup();
        // group 0 -> group 3 and group 1 -> group 2 (64 NICs per group in
        // small(4,4)): disjoint NICs, locals and globals
        let mut flows: Vec<Flow> =
            (0..4).map(|i| Flow::new(i, 200 + i, 8u64 << 20)).collect();
        flows.extend((0..4).map(|i| Flow::new(64 + i, 128 + i, 8u64 << 20)));
        let fl = routed(&t, flows);
        let timed: Vec<TimedFlow> = fl
            .iter()
            .map(|rf| TimedFlow { rf: rf.clone(), start: 0.0 })
            .collect();
        assert_equivalent(DesOpts::default(), &t, &timed);
    }
}
