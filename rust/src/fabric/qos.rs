//! QoS traffic classes (paper §3.1, §4.2.3).
//!
//! Aurora runs the LlBeBdEt profile ("Profile 2"): three bidirectional HPC
//! classes plus a dedicated Ethernet class. Classes get a guaranteed
//! minimum share of contended links and are capped at a maximum share;
//! unused minimum is redistributable. The paper's MPI testing used only
//! HPC Best Effort — the reproduction harness does the same, but the
//! class machinery is exercised by the QoS unit tests and the fabric
//! manager configuration path.


#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TrafficClass {
    /// Barriers, small reductions (§3.1: "low latency operations ... could
    /// run in a high-priority traffic class").
    LowLatency,
    /// Bulk data delivery (adaptively routed, unordered).
    BulkData,
    /// Default HPC class — what the paper's MPI runs used (§4.2.3).
    BestEffort,
    /// IP/RoCE traffic.
    Ethernet,
}

/// Per-class bandwidth policy on a contended link.
#[derive(Debug, Clone, Copy)]
pub struct ClassPolicy {
    /// Guaranteed fraction of link bandwidth when requested.
    pub min_share: f64,
    /// Hard ceiling fraction.
    pub max_share: f64,
    /// Strict-priority level (higher preempts) for latency, not bandwidth.
    pub priority: u8,
}

/// The LlBeBdEt ("Profile 2") QoS profile of §4.2.3.
#[derive(Debug, Clone)]
pub struct QosProfile {
    pub low_latency: ClassPolicy,
    pub bulk_data: ClassPolicy,
    pub best_effort: ClassPolicy,
    pub ethernet: ClassPolicy,
}

impl QosProfile {
    pub fn llbebdet() -> Self {
        Self {
            low_latency: ClassPolicy { min_share: 0.10, max_share: 0.25, priority: 3 },
            bulk_data: ClassPolicy { min_share: 0.30, max_share: 1.00, priority: 1 },
            best_effort: ClassPolicy { min_share: 0.20, max_share: 1.00, priority: 0 },
            ethernet: ClassPolicy { min_share: 0.05, max_share: 0.20, priority: 2 },
        }
    }

    pub fn policy(&self, class: TrafficClass) -> ClassPolicy {
        match class {
            TrafficClass::LowLatency => self.low_latency,
            TrafficClass::BulkData => self.bulk_data,
            TrafficClass::BestEffort => self.best_effort,
            TrafficClass::Ethernet => self.ethernet,
        }
    }

    /// Split one link's bandwidth among classes with active demand.
    ///
    /// Algorithm (matching §3.1's description): every active class first
    /// receives its guaranteed minimum (scaled if minima oversubscribe);
    /// leftover capacity is distributed proportionally to demand, but no
    /// class exceeds its max share. Returns same-order fractions.
    pub fn allocate(&self, demands: &[(TrafficClass, f64)]) -> Vec<f64> {
        let total_demand: f64 = demands.iter().map(|(_, d)| d).sum();
        if total_demand <= 1.0 {
            // uncontended: everyone gets what they ask (max still applies)
            return demands
                .iter()
                .map(|(c, d)| d.min(self.policy(*c).max_share))
                .collect();
        }
        let mut shares: Vec<f64> = demands
            .iter()
            .map(|(c, d)| self.policy(*c).min_share.min(*d))
            .collect();
        let min_sum: f64 = shares.iter().sum();
        if min_sum > 1.0 {
            // minima oversubscribed: scale down proportionally
            for s in &mut shares {
                *s /= min_sum;
            }
            return shares;
        }
        // distribute the remainder by residual demand, capped by max_share
        let mut left = 1.0 - min_sum;
        for _ in 0..8 {
            if left <= 1e-12 {
                break;
            }
            let residuals: Vec<f64> = demands
                .iter()
                .zip(&shares)
                .map(|((c, d), s)| {
                    (d.min(self.policy(*c).max_share) - s).max(0.0)
                })
                .collect();
            let rsum: f64 = residuals.iter().sum();
            if rsum <= 1e-12 {
                break;
            }
            let grant = left.min(rsum);
            for (s, r) in shares.iter_mut().zip(&residuals) {
                *s += grant * r / rsum;
            }
            left -= grant;
        }
        shares
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use TrafficClass::*;

    #[test]
    fn uncontended_gets_demand() {
        let q = QosProfile::llbebdet();
        let s = q.allocate(&[(BestEffort, 0.4), (LowLatency, 0.1)]);
        assert!((s[0] - 0.4).abs() < 1e-9);
        assert!((s[1] - 0.1).abs() < 1e-9);
    }

    #[test]
    fn contended_respects_minimums() {
        let q = QosProfile::llbebdet();
        let s = q.allocate(&[(BestEffort, 2.0), (LowLatency, 2.0)]);
        assert!(s[1] >= q.low_latency.min_share - 1e-9);
        let total: f64 = s.iter().sum();
        assert!(total <= 1.0 + 1e-9);
    }

    #[test]
    fn max_share_caps_ethernet() {
        let q = QosProfile::llbebdet();
        let s = q.allocate(&[(Ethernet, 5.0), (BulkData, 5.0)]);
        assert!(s[0] <= q.ethernet.max_share + 1e-9, "ethernet {}", s[0]);
        // bulk data soaks up what ethernet cannot use
        assert!(s[1] > 0.7);
    }

    #[test]
    fn unused_minimum_is_redistributed() {
        // §3.1: "If a class does not use its minimum bandwidth, other
        // classes may use it"
        let q = QosProfile::llbebdet();
        let s = q.allocate(&[(BestEffort, 3.0), (LowLatency, 0.01)]);
        assert!(s[0] > 0.9, "best effort should absorb idle min: {}", s[0]);
    }

    #[test]
    fn allocation_never_exceeds_link() {
        let q = QosProfile::llbebdet();
        for d in [0.5, 1.0, 3.0, 10.0] {
            let s = q.allocate(&[
                (BestEffort, d),
                (BulkData, d),
                (LowLatency, d),
                (Ethernet, d),
            ]);
            assert!(s.iter().sum::<f64>() <= 1.0 + 1e-9);
        }
    }
}
