//! Link-load bookkeeping shared by the router and the cost tiers.

use crate::topology::LinkId;
use rustc_hash::FxHashMap as HashMap;

/// Accumulated load per directed link. Values are in *bytes* for round
/// evaluation or *flow counts / normalized rates* for adaptive-routing
/// scoring — the router only compares relative magnitudes.
#[derive(Debug, Clone, Default)]
pub struct LoadMap {
    map: HashMap<LinkId, f64>,
}

impl LoadMap {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, link: LinkId, amount: f64) {
        *self.map.entry(link).or_insert(0.0) += amount;
    }

    #[inline]
    pub fn add_path(&mut self, links: &[LinkId], amount: f64) {
        for l in links {
            self.add(*l, amount);
        }
    }

    #[inline]
    pub fn get(&self, link: &LinkId) -> f64 {
        self.map.get(link).copied().unwrap_or(0.0)
    }

    /// Maximum load over the links of a path.
    pub fn max_on(&self, links: &[LinkId]) -> f64 {
        links.iter().map(|l| self.get(l)).fold(0.0, f64::max)
    }

    /// Sum of loads over the links of a path (routing score).
    pub fn sum_on(&self, links: &[LinkId]) -> f64 {
        links.iter().map(|l| self.get(l)).sum()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }

    pub fn iter(&self) -> impl Iterator<Item = (&LinkId, &f64)> {
        self.map.iter()
    }

    /// Hottest link and its load — the congestion hot-spot report the
    /// fabric manager surfaces (§4.3).
    pub fn hottest(&self) -> Option<(LinkId, f64)> {
        self.map
            .iter()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(l, v)| (*l, *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_query() {
        let mut m = LoadMap::new();
        let l1 = LinkId::NicUp(1);
        let l2 = LinkId::NicDown(2);
        m.add(l1, 10.0);
        m.add(l1, 5.0);
        m.add(l2, 3.0);
        assert_eq!(m.get(&l1), 15.0);
        assert_eq!(m.max_on(&[l1, l2]), 15.0);
        assert_eq!(m.sum_on(&[l1, l2]), 18.0);
        assert_eq!(m.hottest().unwrap().0, l1);
    }

    #[test]
    fn missing_is_zero() {
        let m = LoadMap::new();
        assert_eq!(m.get(&LinkId::NicUp(9)), 0.0);
    }
}
