//! Link-load bookkeeping shared by the router and the cost tiers.
//!
//! Two stores with one API:
//!
//! * [`LoadMap`] — dense, [`Topology::link_universe`]-indexed slots with
//!   epoch-stamped touched-slot reset. Built once per router (~8 MiB at
//!   full Aurora) and queried on every adaptive-routing score, where the
//!   old `FxHashMap<LinkId, f64>` lookup dominated router cost
//!   (EXPERIMENTS.md §Raw speed).
//! * [`SparseLoadMap`] — the hash-map implementation, kept for transient
//!   per-call accumulators (round-tier evaluation builds one per call;
//!   a dense map there would allocate the whole universe each time) and
//!   as the baseline arm of the `des_router_dense_load` bench.

use crate::topology::{LinkId, LinkIndexer, Topology};
use rustc_hash::FxHashMap as HashMap;

/// Accumulated load per directed link, dense over the topology's link
/// universe. Values are in *bytes* for round evaluation or *flow counts
/// / normalized rates* for adaptive-routing scoring — the router only
/// compares relative magnitudes.
///
/// `clear` is O(1): slots carry an epoch stamp and a slot is live only
/// when its stamp matches the current epoch, so resetting is one epoch
/// bump (the touched list is kept for iteration and rebuilt lazily).
#[derive(Debug, Clone)]
pub struct LoadMap {
    slots: Vec<f64>,
    stamp: Vec<u32>,
    epoch: u32,
    /// Universe ids minted this epoch, insertion-ordered.
    touched: Vec<u32>,
    /// The [`LinkId`] behind each `touched` entry (for iteration).
    links: Vec<LinkId>,
    ix: LinkIndexer,
}

impl LoadMap {
    pub fn new(topo: &Topology) -> Self {
        let ix = topo.link_indexer();
        let uni = ix.universe();
        Self {
            slots: vec![0.0; uni],
            stamp: vec![0; uni],
            epoch: 1,
            touched: Vec::new(),
            links: Vec::new(),
            ix,
        }
    }

    #[inline]
    pub fn add(&mut self, link: LinkId, amount: f64) {
        let u = self.ix.index(&link) as usize;
        if self.stamp[u] != self.epoch {
            self.stamp[u] = self.epoch;
            self.slots[u] = 0.0;
            self.touched.push(u as u32);
            self.links.push(link);
        }
        self.slots[u] += amount;
    }

    #[inline]
    pub fn add_path(&mut self, links: &[LinkId], amount: f64) {
        for l in links {
            self.add(*l, amount);
        }
    }

    #[inline]
    pub fn get(&self, link: &LinkId) -> f64 {
        let u = self.ix.index(link) as usize;
        if self.stamp[u] == self.epoch {
            self.slots[u]
        } else {
            0.0
        }
    }

    /// Maximum load over the links of a path.
    pub fn max_on(&self, links: &[LinkId]) -> f64 {
        links.iter().map(|l| self.get(l)).fold(0.0, f64::max)
    }

    /// Sum of loads over the links of a path (routing score).
    pub fn sum_on(&self, links: &[LinkId]) -> f64 {
        links.iter().map(|l| self.get(l)).sum()
    }

    /// Number of links carrying load this epoch.
    pub fn len(&self) -> usize {
        self.touched.len()
    }

    pub fn is_empty(&self) -> bool {
        self.touched.is_empty()
    }

    /// O(1) reset: bump the epoch so every slot reads as unminted. On
    /// (u32) epoch wrap-around the stamps are refilled once.
    pub fn clear(&mut self) {
        self.touched.clear();
        self.links.clear();
        if self.epoch == u32::MAX {
            self.stamp.fill(0);
            self.epoch = 1;
        } else {
            self.epoch += 1;
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&LinkId, &f64)> {
        self.links
            .iter()
            .zip(self.touched.iter())
            .map(|(l, &u)| (l, &self.slots[u as usize]))
    }

    /// Hottest link and its load — the congestion hot-spot report the
    /// fabric manager surfaces (§4.3). Ties break to the lowest
    /// [`LinkId`] (deterministic regardless of insertion order).
    pub fn hottest(&self) -> Option<(LinkId, f64)> {
        hottest_of(self.iter())
    }
}

/// The original hash-map load store: no universe allocation, so it stays
/// the right shape for transient per-call accumulators (the round tier's
/// `eval_round`/`eval_timed`) where a dense map would pay an O(universe)
/// build per call.
#[derive(Debug, Clone, Default)]
pub struct SparseLoadMap {
    map: HashMap<LinkId, f64>,
}

impl SparseLoadMap {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add(&mut self, link: LinkId, amount: f64) {
        *self.map.entry(link).or_insert(0.0) += amount;
    }

    #[inline]
    pub fn add_path(&mut self, links: &[LinkId], amount: f64) {
        for l in links {
            self.add(*l, amount);
        }
    }

    #[inline]
    pub fn get(&self, link: &LinkId) -> f64 {
        self.map.get(link).copied().unwrap_or(0.0)
    }

    /// Maximum load over the links of a path.
    pub fn max_on(&self, links: &[LinkId]) -> f64 {
        links.iter().map(|l| self.get(l)).fold(0.0, f64::max)
    }

    /// Sum of loads over the links of a path (routing score).
    pub fn sum_on(&self, links: &[LinkId]) -> f64 {
        links.iter().map(|l| self.get(l)).sum()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }

    pub fn iter(&self) -> impl Iterator<Item = (&LinkId, &f64)> {
        self.map.iter()
    }

    /// Hottest link and its load; ties break to the lowest [`LinkId`]
    /// (the old `max_by` answer depended on hash iteration order).
    pub fn hottest(&self) -> Option<(LinkId, f64)> {
        hottest_of(self.map.iter())
    }
}

/// Shared hottest-link scan: max by load, ties to the lowest link id, so
/// the answer is a pure function of the (link, load) *set*.
fn hottest_of<'a, I>(it: I) -> Option<(LinkId, f64)>
where
    I: Iterator<Item = (&'a LinkId, &'a f64)>,
{
    let mut best: Option<(LinkId, f64)> = None;
    for (l, v) in it {
        let better = match &best {
            None => true,
            Some((bl, bv)) => match v.total_cmp(bv) {
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Equal => *l < *bl,
                std::cmp::Ordering::Less => false,
            },
        };
        if better {
            best = Some((*l, *v));
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;

    fn topo() -> Topology {
        Topology::new(&AuroraConfig::small(4, 4))
    }

    #[test]
    fn add_and_query() {
        let t = topo();
        let mut m = LoadMap::new(&t);
        let l1 = LinkId::NicUp(1);
        let l2 = LinkId::NicDown(2);
        m.add(l1, 10.0);
        m.add(l1, 5.0);
        m.add(l2, 3.0);
        assert_eq!(m.get(&l1), 15.0);
        assert_eq!(m.max_on(&[l1, l2]), 15.0);
        assert_eq!(m.sum_on(&[l1, l2]), 18.0);
        assert_eq!(m.len(), 2);
        assert_eq!(m.hottest().unwrap().0, l1);
    }

    #[test]
    fn missing_is_zero() {
        let t = topo();
        let m = LoadMap::new(&t);
        assert_eq!(m.get(&LinkId::NicUp(9)), 0.0);
        assert!(m.is_empty());
        assert!(m.hottest().is_none());
    }

    #[test]
    fn clear_resets_without_reallocating() {
        let t = topo();
        let mut m = LoadMap::new(&t);
        m.add(LinkId::NicUp(3), 7.0);
        assert_eq!(m.len(), 1);
        m.clear();
        assert!(m.is_empty());
        assert_eq!(m.get(&LinkId::NicUp(3)), 0.0);
        // a re-add after the epoch bump starts from zero again
        m.add(LinkId::NicUp(3), 2.0);
        assert_eq!(m.get(&LinkId::NicUp(3)), 2.0);
        assert_eq!(m.hottest().unwrap(), (LinkId::NicUp(3), 2.0));
    }

    #[test]
    fn hottest_tie_breaks_to_lowest_link_id() {
        // equal loads: the winner must be the lowest LinkId no matter
        // the insertion order (the old hash-map max_by was iteration-
        // order dependent)
        let t = topo();
        let a = LinkId::NicUp(1);
        let b = LinkId::NicUp(5);
        let c = LinkId::NicDown(0);
        for order in [[c, b, a], [a, b, c], [b, a, c]] {
            let mut dense = LoadMap::new(&t);
            let mut sparse = SparseLoadMap::new();
            for l in order {
                dense.add(l, 4.0);
                sparse.add(l, 4.0);
            }
            assert_eq!(dense.hottest().unwrap().0, a, "{order:?}");
            assert_eq!(sparse.hottest().unwrap().0, a, "{order:?}");
        }
    }

    #[test]
    fn dense_and_sparse_agree() {
        let t = topo();
        let mut dense = LoadMap::new(&t);
        let mut sparse = SparseLoadMap::new();
        let links = [
            LinkId::NicUp(0),
            LinkId::NicDown(7),
            LinkId::Local { group: 1, a: 0, b: 2 },
            LinkId::Global { src: 0, dst: 3, idx: 1 },
        ];
        for (i, l) in links.iter().enumerate() {
            dense.add(*l, (i + 1) as f64);
            sparse.add(*l, (i + 1) as f64);
        }
        for l in &links {
            assert_eq!(dense.get(l), sparse.get(l));
        }
        assert_eq!(dense.len(), sparse.len());
        assert_eq!(dense.max_on(&links), sparse.max_on(&links));
        assert_eq!(dense.sum_on(&links), sparse.sum_on(&links));
        assert_eq!(dense.hottest(), sparse.hottest());
    }
}
