//! Open-loop arrival tier: trace- and Poisson-driven RPC traffic on the
//! streaming DES executor, at bounded memory for any trace length.
//!
//! Everything below ROADMAP item 2: an [`ArrivalSource`] yields
//! individual timed transfers (millions of small RPC-style flows over
//! simulated hours); [`OpenLoopSource`] adapts a source into the
//! executor's [`RoundSource`] by batching arrivals into fixed *quantum*
//! windows of [`NO_KEY`] nodes — no frontier dependencies, released
//! purely by their arrival-time floors — and declaring each window's
//! start through [`RoundSource::next_round_not_before`], so the executor
//! materializes a window only when the simulated clock reaches it
//! instead of pulling the whole trace up front. Completed windows retire
//! through the refcount frontier (zero refs by construction), which is
//! what keeps peak-live nodes proportional to *concurrency*, not trace
//! length — proven by `tests/open_loop.rs` and the gated
//! `des_open_loop_steady` bench (`open_loop_live_headroom` floor).
//!
//! Metrics are windowed steady-state, not a single makespan: the
//! [`SteadyCollector`] banks each completion into a cumulative
//! deterministic log-bucket latency histogram the moment it happens
//! (completions leave the executor in non-decreasing time order, so
//! fixed *metric windows* seal in order), and tracks per-class backlog
//! and peak in-flight flows. The final [`SteadyState`] carries sustained
//! throughput and p50/p99/p999 completion latency. All state is O(peak
//! concurrency + histogram), never O(total arrivals).
//!
//! With a [`ServicePolicy`] armed ([`OpenLoopSource::policies`] /
//! `DesOpts::policies`), the same collector also accounts graceful
//! degradation: per-class `shed` (admission control), `abandoned`
//! (deadlines), `failed` (fault policy) and `hedged` counters, plus
//! goodput — completions within their class deadline — next to raw
//! throughput. Failed and abandoned requests retire from the backlog
//! at their failure instant and never enter the latency histogram.
//!
//! Determinism: [`PoissonArrivals`] seeds [`Pcg`] with the same
//! name-derived `fnv1a(name) ^ campaign_seed` convention the campaign
//! layer uses everywhere else (stream [`ARRIVAL_STREAM`]) — there is no
//! wall-clock anywhere in the arrival path, so serial and
//! `DES_THREADS=8` runs produce byte-identical reports.

use super::degrade::{Admission, ServicePolicy};
use super::des::{DesScratch, DesSim, FlowOutcome, StreamResult};
use super::workload::{RoundSource, StreamNode, NO_KEY};
use super::{Flow, RoutedFlow, Router};
use crate::util::rng::Pcg;
use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::BufRead;

/// Pcg stream id for arrival processes (the workload layer uses
/// `0x5ce0`, the router `seed ^ 0x707e`; arrivals get their own stream
/// so an open-loop scenario's arrival pattern is independent of both).
pub const ARRIVAL_STREAM: u64 = 0xa771;

/// One open-loop arrival: a transfer of `bytes` from endpoint `src` to
/// endpoint `dst` entering the fabric at absolute time `t`, tagged with
/// a small service-class id (an index into the scenario's RPC mix —
/// per-class backlog is reported per id).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub t: f64,
    pub src: u32,
    pub dst: u32,
    pub bytes: u64,
    pub class: u8,
}

/// A stream of [`Arrival`]s in non-decreasing time order ([`OpenLoopSource`]
/// asserts the order). `None` ends the trace.
pub trait ArrivalSource {
    fn next_arrival(&mut self) -> Option<Arrival>;
}

/// One entry of an RPC size mix: transfers of `bytes` drawn with
/// relative `weight`. The entry's index in the mix slice is the
/// arrival's service class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RpcClass {
    pub bytes: u64,
    pub weight: f64,
}

/// Poisson arrival process over a uniform random endpoint mix:
/// exponential inter-arrival times at `rate` arrivals/second,
/// independent uniform (src, dst) pairs (re-drawn on src == dst), and a
/// weighted size mix. Seeded deterministically — pass
/// `fnv1a(name) ^ campaign_seed` like every other campaign RNG; the
/// generator never reads a clock.
pub struct PoissonArrivals {
    rng: Pcg,
    rate: f64,
    remaining: u64,
    t: f64,
    endpoints: Vec<u32>,
    mix: Vec<RpcClass>,
    weight_total: f64,
}

impl PoissonArrivals {
    pub fn new(
        seed: u64,
        rate: f64,
        count: u64,
        endpoints: Vec<u32>,
        mix: Vec<RpcClass>,
    ) -> Self {
        assert!(rate > 0.0 && rate.is_finite(), "arrival rate {rate}");
        assert!(endpoints.len() >= 2, "need >= 2 endpoints");
        assert!(!mix.is_empty(), "empty RPC mix");
        assert!(mix.len() <= 256, "class ids are u8");
        let weight_total = mix.iter().map(|c| c.weight).sum::<f64>();
        assert!(weight_total > 0.0, "mix weights sum to {weight_total}");
        Self {
            rng: Pcg::with_stream(seed, ARRIVAL_STREAM),
            rate,
            remaining: count,
            t: 0.0,
            endpoints,
            mix,
            weight_total,
        }
    }
}

impl ArrivalSource for PoissonArrivals {
    fn next_arrival(&mut self) -> Option<Arrival> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        // exponential inter-arrival; 1 - u in (0, 1] so ln() is finite
        let u = self.rng.gen_f64();
        self.t += -(1.0 - u).ln() / self.rate;
        let n = self.endpoints.len();
        let src = self.endpoints[self.rng.gen_usize(n)];
        let dst = loop {
            let d = self.endpoints[self.rng.gen_usize(n)];
            if d != src {
                break d;
            }
        };
        let mut w = self.rng.gen_f64() * self.weight_total;
        let mut class = self.mix.len() - 1;
        for (i, c) in self.mix.iter().enumerate() {
            if w < c.weight {
                class = i;
                break;
            }
            w -= c.weight;
        }
        Some(Arrival {
            t: self.t,
            src,
            dst,
            bytes: self.mix[class].bytes,
            class: class as u8,
        })
    }
}

/// File-backed trace reader: whitespace-separated
/// `t_seconds src dst bytes [class]` per line, `#`-prefixed and blank
/// lines skipped. Panics with the 1-based line number on malformed
/// input, non-finite or decreasing timestamps, aliased endpoints, or —
/// when a bound is installed via [`TraceArrivals::with_endpoint_bound`]
/// — out-of-range endpoint ids (a corrupt trace should fail loudly,
/// not silently misprice).
pub struct TraceArrivals<R: BufRead> {
    reader: R,
    line: usize,
    last_t: f64,
    buf: String,
    /// Exclusive endpoint-id upper bound (`None`: unchecked — the
    /// router's topology lookup is then the only guard).
    endpoint_bound: Option<u32>,
}

impl<R: BufRead> TraceArrivals<R> {
    pub fn new(reader: R) -> Self {
        Self {
            reader,
            line: 0,
            last_t: 0.0,
            buf: String::new(),
            endpoint_bound: None,
        }
    }

    /// Reject endpoint ids `>= bound` at parse time (pass the
    /// topology's compute-endpoint count), so a rank-mangled trace
    /// fails with its line number instead of a routing panic deep in
    /// the executor.
    pub fn with_endpoint_bound(mut self, bound: u32) -> Self {
        self.endpoint_bound = Some(bound);
        self
    }
}

impl<R: BufRead> ArrivalSource for TraceArrivals<R> {
    fn next_arrival(&mut self) -> Option<Arrival> {
        loop {
            self.buf.clear();
            let n = self
                .reader
                .read_line(&mut self.buf)
                .unwrap_or_else(|e| panic!("trace read error: {e}"));
            if n == 0 {
                return None;
            }
            self.line += 1;
            let line = self.buf.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let mut field = |name: &str| {
                it.next().unwrap_or_else(|| {
                    panic!("trace line {}: missing {name}", self.line)
                })
            };
            let t: f64 = field("t").parse().unwrap_or_else(|e| {
                panic!("trace line {}: bad t: {e}", self.line)
            });
            let src: u32 = field("src").parse().unwrap_or_else(|e| {
                panic!("trace line {}: bad src: {e}", self.line)
            });
            let dst: u32 = field("dst").parse().unwrap_or_else(|e| {
                panic!("trace line {}: bad dst: {e}", self.line)
            });
            let bytes: u64 = field("bytes").parse().unwrap_or_else(|e| {
                panic!("trace line {}: bad bytes: {e}", self.line)
            });
            let class: u8 = match it.next() {
                None => 0,
                Some(c) => c.parse().unwrap_or_else(|e| {
                    panic!("trace line {}: bad class: {e}", self.line)
                }),
            };
            assert!(
                t.is_finite(),
                "trace line {}: non-finite timestamp {t}",
                self.line
            );
            assert!(
                t >= self.last_t,
                "trace line {}: timestamp {t} decreases (last {})",
                self.line,
                self.last_t
            );
            assert!(src != dst, "trace line {}: src == dst", self.line);
            if let Some(bound) = self.endpoint_bound {
                for (name, ep) in [("src", src), ("dst", dst)] {
                    assert!(
                        ep < bound,
                        "trace line {}: {name} {ep} out of range \
                         (endpoints < {bound})",
                        self.line
                    );
                }
            }
            self.last_t = t;
            return Some(Arrival { t, src, dst, bytes, class });
        }
    }
}

// ------------------------------------------------------------- adapter

/// Adapts an [`ArrivalSource`] into the streaming executor's
/// [`RoundSource`]: arrivals are batched into fixed `quantum` windows
/// (one non-empty window per round), routed on demand, and emitted as
/// [`NO_KEY`] transfer nodes whose release floor is the exact arrival
/// time. [`RoundSource::next_round_not_before`] reports the next
/// window's start, so the executor defers materialization until the
/// clock gets there — at any instant only the windows overlapping live
/// flows are materialized. Floors sit inside their window
/// (`floor >= window start`), so open-loop runs never clamp
/// (`late_releases == 0`) and a short trace is 1e-9-equivalent to
/// `run_dag` on [`super::workload::DagWorkload::from_timed`] over the
/// same transfers.
pub struct OpenLoopSource<'c, 'r, 't, S: ArrivalSource> {
    arrivals: S,
    router: &'r mut Router<'t>,
    quantum: f64,
    pending: Option<Arrival>,
    last_t: f64,
    collector: Option<&'c RefCell<SteadyCollector>>,
    /// Armed overload-control policy + its token-bucket state: arrivals
    /// are admission-checked *before* routing, and shed ones never
    /// materialize (they are counted by the collector instead).
    policy: Option<(ServicePolicy, Admission)>,
    /// Service class of each node emitted in the current round, in
    /// emission order — backs [`RoundSource::node_class`], which the
    /// executor queries only while a policy is armed.
    classes: Vec<u8>,
}

impl<'c, 'r, 't, S: ArrivalSource> OpenLoopSource<'c, 'r, 't, S> {
    pub fn new(arrivals: S, router: &'r mut Router<'t>, quantum: f64) -> Self {
        assert!(quantum > 0.0 && quantum.is_finite(), "quantum {quantum}");
        Self {
            arrivals,
            router,
            quantum,
            pending: None,
            last_t: 0.0,
            collector: None,
            policy: None,
            classes: Vec::new(),
        }
    }

    /// Attach a shared metrics collector: every emitted node's
    /// (arrival time, bytes, class) is recorded at materialization, in
    /// node-id order (the executor numbers nodes in emission order).
    pub fn collect(mut self, c: &'c RefCell<SteadyCollector>) -> Self {
        self.collector = Some(c);
        self
    }

    /// Arm a [`ServicePolicy`]: per-class token-bucket + backlog-
    /// threshold admission control runs at arrival time (shed requests
    /// never touch the router or the executor), and emitted nodes are
    /// class-tagged for the executor's deadline/hedge/budget controls.
    /// Backlog thresholds read the attached collector's live per-class
    /// backlog (no collector: backlog reads as 0). An inert policy
    /// ([`ServicePolicy::is_inert`]) sheds nothing and leaves the
    /// emitted stream bit-identical to an unarmed source.
    ///
    /// One documented weakening of the bounded-memory throttle: a
    /// window whose every arrival is shed yields an *empty* round, and
    /// the executor's empty-round skip then pulls the next window
    /// without re-consulting `next_round_not_before` — arrival floors
    /// are still honored exactly, only materialization may run ahead of
    /// the clock by those fully-shed windows.
    pub fn policies(mut self, p: ServicePolicy) -> Self {
        let adm = Admission::new(&p);
        self.policy = Some((p, adm));
        self
    }

    /// Pull the next arrival (through the one-arrival lookahead) and
    /// enforce the non-decreasing contract.
    fn pull(&mut self) -> Option<Arrival> {
        let a = self.pending.take().or_else(|| self.arrivals.next_arrival())?;
        assert!(
            a.t.is_finite() && a.t >= self.last_t,
            "arrival time {} decreases (last {})",
            a.t,
            self.last_t
        );
        self.last_t = a.t;
        Some(a)
    }

    fn window_start(&self, t: f64) -> f64 {
        (t / self.quantum).floor() * self.quantum
    }

    fn emit(&mut self, a: Arrival) -> StreamNode {
        let f = Flow::new(a.src, a.dst, a.bytes);
        let path = self.router.route(&f);
        if let Some(c) = self.collector {
            c.borrow_mut().arrive(a);
        }
        StreamNode::Xfer {
            a: NO_KEY,
            b: NO_KEY,
            rf: RoutedFlow { flow: f, path },
            start: a.t,
        }
    }

    /// Admission-check `a` against the armed policy (if any): an
    /// admitted arrival routes and emits, a shed one is only counted.
    fn admit_emit(&mut self, a: Arrival) -> Option<StreamNode> {
        if let Some((pol, adm)) = self.policy.as_mut() {
            let backlog = self
                .collector
                .map_or(0, |c| c.borrow().backlog(a.class));
            if !adm.admit(pol, a.class, a.t, backlog) {
                if let Some(c) = self.collector {
                    c.borrow_mut().shed(a);
                }
                return None;
            }
        }
        self.classes.push(a.class);
        Some(self.emit(a))
    }
}

impl<S: ArrivalSource> RoundSource for OpenLoopSource<'_, '_, '_, S> {
    fn next_round(&mut self) -> Option<Vec<StreamNode>> {
        let first = self.pull()?;
        let end = self.window_start(first.t) + self.quantum;
        self.classes.clear();
        let mut nodes = Vec::new();
        if let Some(n) = self.admit_emit(first) {
            nodes.push(n);
        }
        loop {
            match self.pull() {
                None => break,
                Some(a) if a.t < end => {
                    if let Some(n) = self.admit_emit(a) {
                        nodes.push(n);
                    }
                }
                Some(a) => {
                    self.pending = Some(a);
                    break;
                }
            }
        }
        Some(nodes)
    }

    fn next_round_not_before(&mut self) -> f64 {
        if self.pending.is_none() {
            self.pending = self.arrivals.next_arrival();
        }
        match &self.pending {
            Some(a) => self.window_start(a.t),
            None => 0.0, // exhausted: the next `next_round` returns None
        }
    }

    fn node_class(&self, i: usize) -> u8 {
        self.classes.get(i).copied().unwrap_or(0)
    }
}

// ----------------------------------------------------------- collector

/// Number of log buckets in the latency histogram: positive-f64 bit
/// pattern shifted down 50 (11 exponent bits + top 2 mantissa bits),
/// i.e. 4 geometric buckets per octave, ~19% relative bucket width.
const HIST_BUCKETS: usize = 1 << 13;

/// Deterministic log-bucket histogram over positive f64 samples. The
/// bucket of `x` is `x.to_bits() >> 50` — pure integer manipulation, so
/// identical across runs and thread counts. Quantiles report the
/// bucket's lower edge (`bits = bucket << 50`), biasing every quantile
/// down by at most one bucket width.
#[derive(Clone)]
struct LatHist {
    count: Vec<u64>,
    total: u64,
}

impl LatHist {
    fn new() -> Self {
        Self { count: vec![0; HIST_BUCKETS], total: 0 }
    }

    fn add(&mut self, x: f64) {
        debug_assert!(x >= 0.0, "negative latency {x}");
        let b = ((x.max(0.0).to_bits() >> 50) as usize).min(HIST_BUCKETS - 1);
        self.count[b] += 1;
        self.total += 1;
    }

    /// Lower edge of the bucket holding the `q`-quantile sample
    /// (rank `ceil(q * total)`, clamped to [1, total]); 0.0 when empty.
    fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q * self.total as f64).ceil() as u64)
            .clamp(1, self.total);
        let mut seen = 0u64;
        for (b, &c) in self.count.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return f64::from_bits((b as u64) << 50);
            }
        }
        f64::from_bits(((HIST_BUCKETS - 1) as u64) << 50)
    }
}

/// Per-node metadata held only while the flow is in flight.
#[derive(Clone, Copy)]
struct NodeMeta {
    t_arr: f64,
    bytes: u64,
    class: u8,
    done: bool,
}

/// Windowed steady-state metrics, banked incrementally: latency samples
/// fold into a cumulative [`LatHist`] the moment each flow finishes, and
/// fixed `window`-second metric windows seal in completion-time order
/// (the executor emits completions in non-decreasing time). Live state
/// is the in-flight metadata deque plus O(1) scalars and the fixed-size
/// histogram — bounded at any trace length.
pub struct SteadyCollector {
    window: f64,
    meta: VecDeque<NodeMeta>,
    meta_base: u32,
    hist: LatHist,
    /// Cumulative *accepted* arrivals / completions per class (shed
    /// requests are counted in `shed_c` only).
    arrived: Vec<u64>,
    completed_c: Vec<u64>,
    /// Live per-class backlog: accepted, not yet completed / failed /
    /// abandoned. The admission layer's backlog threshold reads this.
    backlog_c: Vec<u64>,
    /// Max instantaneous per-class backlog.
    max_backlog: Vec<u64>,
    /// Per-class degradation counts ([`ServicePolicy`] controls).
    shed_c: Vec<u64>,
    abandoned_c: Vec<u64>,
    failed_c: Vec<u64>,
    hedged_c: Vec<u64>,
    /// Armed policy, for the goodput cut: a completion is *goodput*
    /// when its latency is within its class deadline. `None` (or an
    /// inert policy): every completion is goodput.
    policy: Option<ServicePolicy>,
    deadline_met: u64,
    completed: u64,
    completed_bytes: u64,
    last_finish: f64,
    inflight: usize,
    peak_inflight: usize,
    /// Current metric window [seal - window, seal).
    seal: f64,
    win_flows: u64,
    win_bytes: u64,
    windows: u64,
    peak_win_flows: u64,
}

impl SteadyCollector {
    pub fn new(window: f64) -> Self {
        assert!(window > 0.0 && window.is_finite(), "window {window}");
        Self {
            window,
            meta: VecDeque::new(),
            meta_base: 0,
            hist: LatHist::new(),
            arrived: Vec::new(),
            completed_c: Vec::new(),
            backlog_c: Vec::new(),
            max_backlog: Vec::new(),
            shed_c: Vec::new(),
            abandoned_c: Vec::new(),
            failed_c: Vec::new(),
            hedged_c: Vec::new(),
            policy: None,
            deadline_met: 0,
            completed: 0,
            completed_bytes: 0,
            last_finish: 0.0,
            inflight: 0,
            peak_inflight: 0,
            seal: window,
            win_flows: 0,
            win_bytes: 0,
            windows: 0,
            peak_win_flows: 0,
        }
    }

    /// Install the run's [`ServicePolicy`] so goodput can be cut
    /// against per-class deadlines.
    pub fn with_policy(mut self, p: ServicePolicy) -> Self {
        self.policy = Some(p);
        self
    }

    fn class_slot(&mut self, class: u8) {
        let need = class as usize + 1;
        if self.arrived.len() < need {
            self.arrived.resize(need, 0);
            self.completed_c.resize(need, 0);
            self.backlog_c.resize(need, 0);
            self.max_backlog.resize(need, 0);
            self.shed_c.resize(need, 0);
            self.abandoned_c.resize(need, 0);
            self.failed_c.resize(need, 0);
            self.hedged_c.resize(need, 0);
        }
    }

    /// Live backlog of `class` (accepted, not yet retired) — what the
    /// admission layer's backlog threshold sheds against.
    pub fn backlog(&self, class: u8) -> u64 {
        self.backlog_c.get(class as usize).copied().unwrap_or(0)
    }

    /// Count a load-shed arrival (admission control rejected it before
    /// it reached the router/executor).
    pub fn shed(&mut self, a: Arrival) {
        self.class_slot(a.class);
        self.shed_c[a.class as usize] += 1;
    }

    /// Record an accepted arrival at materialization time. Must be
    /// called in node-id order (the [`OpenLoopSource`] adapter
    /// guarantees it).
    fn arrive(&mut self, a: Arrival) {
        self.class_slot(a.class);
        self.arrived[a.class as usize] += 1;
        self.backlog_c[a.class as usize] += 1;
        let backlog = self.backlog_c[a.class as usize];
        let mb = &mut self.max_backlog[a.class as usize];
        *mb = (*mb).max(backlog);
        self.inflight += 1;
        self.peak_inflight = self.peak_inflight.max(self.inflight);
        self.meta.push_back(NodeMeta {
            t_arr: a.t,
            bytes: a.bytes,
            class: a.class,
            done: false,
        });
    }

    /// Retire node `id`'s in-flight metadata (shared by every terminal
    /// outcome); returns its [`NodeMeta`], or `None` if the node was
    /// already retired (idempotence — both hedge twins can fail in one
    /// fault sweep, notifying twice).
    fn retire(&mut self, id: u32) -> Option<NodeMeta> {
        if id < self.meta_base {
            return None; // retired and popped
        }
        let i = (id - self.meta_base) as usize;
        let m = self.meta[i];
        if m.done {
            return None;
        }
        self.meta[i].done = true;
        self.backlog_c[m.class as usize] -= 1;
        self.inflight -= 1;
        while let Some(front) = self.meta.front() {
            if !front.done {
                break;
            }
            self.meta.pop_front();
            self.meta_base += 1;
        }
        Some(m)
    }

    /// Bank node `id`'s completion at absolute time `t` (the streaming
    /// sink callback). Completion times are non-decreasing, so metric
    /// windows seal in order.
    pub fn finish(&mut self, id: u32, t: f64) {
        while t >= self.seal {
            self.windows += 1;
            self.peak_win_flows = self.peak_win_flows.max(self.win_flows);
            self.win_flows = 0;
            self.win_bytes = 0;
            self.seal += self.window;
        }
        let m = match self.retire(id) {
            Some(m) => m,
            None => {
                debug_assert!(false, "node {id} finished twice");
                return;
            }
        };
        let lat = t - m.t_arr;
        self.hist.add(lat);
        if self
            .policy
            .as_ref()
            .map_or(true, |p| lat <= p.class(m.class).deadline)
        {
            self.deadline_met += 1;
        }
        self.completed += 1;
        self.completed_bytes += m.bytes;
        self.completed_c[m.class as usize] += 1;
        self.win_flows += 1;
        self.win_bytes += m.bytes;
        self.last_finish = self.last_finish.max(t);
    }

    /// Retire node `id` *without* a completion: the fault policy failed
    /// it (`abandoned == false`) or a deadline abandoned it
    /// (`abandoned == true`). No latency sample is banked — failed and
    /// abandoned requests must not poison the quantiles — and the
    /// request leaves the backlog (the PR-9 phantom-backlog bugfix).
    /// Idempotent: a second call for the same node is a no-op.
    pub fn fail(&mut self, id: u32, _t: f64, abandoned: bool) {
        let m = match self.retire(id) {
            Some(m) => m,
            None => return,
        };
        if abandoned {
            self.abandoned_c[m.class as usize] += 1;
        } else {
            self.failed_c[m.class as usize] += 1;
        }
    }

    /// Count a hedge spawn for in-flight node `id` (informational; the
    /// node still reaches a terminal outcome later).
    pub fn hedged(&mut self, id: u32) {
        if id < self.meta_base {
            return;
        }
        let m = self.meta[(id - self.meta_base) as usize];
        self.hedged_c[m.class as usize] += 1;
    }

    /// Fold the (possibly partial) final window and summarize.
    pub fn into_summary(mut self) -> SteadyState {
        if self.win_flows > 0 {
            self.windows += 1;
            self.peak_win_flows = self.peak_win_flows.max(self.win_flows);
        }
        let span = self.last_finish;
        SteadyState {
            arrivals: self.arrived.iter().sum(),
            completed: self.completed,
            completed_bytes: self.completed_bytes,
            duration: span,
            throughput_flows: if span > 0.0 {
                self.completed as f64 / span
            } else {
                0.0
            },
            throughput_bytes: if span > 0.0 {
                self.completed_bytes as f64 / span
            } else {
                0.0
            },
            goodput_flows: if span > 0.0 {
                self.deadline_met as f64 / span
            } else {
                0.0
            },
            deadline_met: self.deadline_met,
            p50: self.hist.quantile(0.50),
            p99: self.hist.quantile(0.99),
            p999: self.hist.quantile(0.999),
            max_backlog: self.max_backlog,
            shed: self.shed_c,
            abandoned: self.abandoned_c,
            failed: self.failed_c,
            hedged: self.hedged_c,
            peak_inflight: self.peak_inflight,
            windows: self.windows,
        }
    }
}

/// Steady-state summary of one open-loop run (campaign schema v3
/// `steady_state` block). Latency quantiles are log-bucket lower edges
/// (deterministic; see [`SteadyCollector`]); throughput is sustained
/// over the whole run (completions / last completion time).
#[derive(Debug, Clone, PartialEq)]
pub struct SteadyState {
    /// Accepted arrivals (offered load minus `shed`).
    pub arrivals: u64,
    pub completed: u64,
    /// Total payload bytes of completed transfers.
    pub completed_bytes: u64,
    /// Last completion time (seconds) — the steady-state span.
    pub duration: f64,
    /// Sustained completions per second.
    pub throughput_flows: f64,
    /// Sustained payload bytes per second.
    pub throughput_bytes: f64,
    /// Sustained *deadline-met* completions per second — the service's
    /// goodput under a [`ServicePolicy`]. Equals `throughput_flows`
    /// when no (or an inert) policy is armed; structurally so with
    /// deadlines armed too, since `EV_DEADLINE` abandons a request the
    /// instant its SLO expires, so every completion that does land is
    /// within deadline.
    pub goodput_flows: f64,
    /// Completions whose latency was within their class deadline.
    pub deadline_met: u64,
    pub p50: f64,
    pub p99: f64,
    pub p999: f64,
    /// Max instantaneous backlog (accepted - retired) per class id.
    pub max_backlog: Vec<u64>,
    /// Arrivals rejected by admission control, per class id.
    pub shed: Vec<u64>,
    /// Requests abandoned by their deadline, per class id.
    pub abandoned: Vec<u64>,
    /// Requests failed by the fault policy, per class id (excluded
    /// from the latency histogram and retired from the backlog).
    pub failed: Vec<u64>,
    /// Hedge twins spawned, per class id.
    pub hedged: Vec<u64>,
    /// Peak concurrently in-flight flows seen by the collector.
    pub peak_inflight: usize,
    /// Metric windows sealed (including the final partial one).
    pub windows: u64,
}

/// Run an [`ArrivalSource`] open-loop on the streaming executor and
/// collect steady-state metrics: the one-call entry the campaign layer,
/// the CLI and the benches share. `quantum` is the materialization
/// window (arrival batching granularity), `window` the metric window.
pub fn run_open_loop<S: ArrivalSource>(
    sim: &DesSim<'_>,
    scratch: &mut DesScratch,
    arrivals: S,
    router: &mut Router<'_>,
    quantum: f64,
    window: f64,
) -> (StreamResult, SteadyState) {
    let mut coll = SteadyCollector::new(window);
    let policy = sim.opts().policies.clone();
    if let Some(p) = policy.clone() {
        coll = coll.with_policy(p);
    }
    let coll = RefCell::new(coll);
    let mut src = OpenLoopSource::new(arrivals, router, quantum).collect(&coll);
    if let Some(p) = policy {
        src = src.policies(p);
    }
    let res = sim.session(scratch).stream_outcomes(&mut src, |id, t, o| {
        let mut c = coll.borrow_mut();
        match o {
            FlowOutcome::Finished => c.finish(id, t),
            FlowOutcome::Failed => c.fail(id, t, false),
            FlowOutcome::Abandoned => c.fail(id, t, true),
            FlowOutcome::Hedged => c.hedged(id),
        }
    });
    drop(src);
    (res, coll.into_inner().into_summary())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson(seed: u64, n: u64) -> PoissonArrivals {
        PoissonArrivals::new(
            seed,
            1000.0,
            n,
            vec![0, 1, 2, 3, 4, 5, 6, 7],
            vec![
                RpcClass { bytes: 4096, weight: 0.7 },
                RpcClass { bytes: 65536, weight: 0.3 },
            ],
        )
    }

    #[test]
    fn poisson_is_deterministic_and_ordered() {
        let a: Vec<Arrival> =
            std::iter::from_fn(|| poisson(42, 0).next_arrival()).collect();
        assert!(a.is_empty());
        let mut s1 = poisson(42, 500);
        let mut s2 = poisson(42, 500);
        let mut last = 0.0;
        for _ in 0..500 {
            let x = s1.next_arrival().unwrap();
            let y = s2.next_arrival().unwrap();
            assert_eq!(x, y, "same seed must replay identically");
            assert!(x.t >= last && x.t.is_finite());
            assert!(x.src != x.dst);
            assert!((x.class as usize) < 2);
            last = x.t;
        }
        assert!(s1.next_arrival().is_none());
        let z = poisson(43, 1).next_arrival().unwrap();
        let w = poisson(42, 1).next_arrival().unwrap();
        assert!(z != w, "different seeds must differ");
    }

    #[test]
    fn trace_reader_parses_and_defaults_class() {
        let trace = "# comment\n\n0.5 3 9 4096 1\n 1.25 2 7 128 \n2.0 1 4 64 2\n";
        let mut src = TraceArrivals::new(trace.as_bytes());
        let a = src.next_arrival().unwrap();
        assert_eq!(
            a,
            Arrival { t: 0.5, src: 3, dst: 9, bytes: 4096, class: 1 }
        );
        let b = src.next_arrival().unwrap();
        assert_eq!(b.class, 0, "class column is optional");
        assert_eq!(b.bytes, 128);
        assert_eq!(src.next_arrival().unwrap().class, 2);
        assert!(src.next_arrival().is_none());
        assert!(src.next_arrival().is_none(), "stays exhausted");
    }

    #[test]
    #[should_panic(expected = "line 2")]
    fn trace_reader_rejects_decreasing_time() {
        let mut src = TraceArrivals::new("1.0 0 1 64\n0.5 1 2 64\n".as_bytes());
        src.next_arrival();
        src.next_arrival();
    }

    #[test]
    #[should_panic(expected = "bad bytes")]
    fn trace_reader_rejects_garbage() {
        TraceArrivals::new("0.0 1 2 many\n".as_bytes()).next_arrival();
    }

    #[test]
    fn hist_quantiles_bracket_samples() {
        let mut h = LatHist::new();
        for i in 1..=1000u64 {
            h.add(i as f64 * 1e-6); // 1us .. 1ms
        }
        let p50 = h.quantile(0.50);
        let p99 = h.quantile(0.99);
        let p999 = h.quantile(0.999);
        // lower bucket edges: within one bucket (~19%) below the sample
        assert!(p50 <= 500e-6 && p50 >= 500e-6 / 1.25, "{p50}");
        assert!(p99 <= 990e-6 && p99 >= 990e-6 / 1.25, "{p99}");
        assert!(p999 <= 1000e-6 && p999 >= 1000e-6 / 1.25, "{p999}");
        assert!(p50 <= p99 && p99 <= p999);
    }

    #[test]
    fn collector_banks_in_flight_only() {
        let mut c = SteadyCollector::new(1.0);
        for i in 0..100u32 {
            c.arrive(Arrival {
                t: i as f64 * 0.1,
                src: 0,
                dst: 1,
                bytes: 10,
                class: (i % 3) as u8,
            });
            c.finish(i, i as f64 * 0.1 + 0.05);
        }
        assert!(c.meta.is_empty(), "retired metadata must leave the deque");
        assert_eq!(c.peak_inflight, 1);
        let s = c.into_summary();
        assert_eq!(s.arrivals, 100);
        assert_eq!(s.completed, 100);
        assert_eq!(s.max_backlog, vec![1, 1, 1]);
        assert!((s.p50 - 0.05).abs() / 0.05 < 0.25, "{}", s.p50);
        assert!(s.windows >= 10);
    }
}
