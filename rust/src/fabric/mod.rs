//! The Slingshot fabric simulator (paper §3).
//!
//! Three fidelity tiers (DESIGN.md §2), all sharing the same topology,
//! routing and QoS models:
//!
//! * [`des`] — flow-level event-driven simulation with max-min fair
//!   bandwidth sharing, adaptive routing and the congestion-management
//!   behaviour of §3.1 (incast contributor throttling, victim protection).
//!   Two solvers: the incremental component re-solver ([`DesSim::run`])
//!   that scales to campaign-sized flow counts, and the dense
//!   full-recompute oracle ([`DesSim::run_oracle`]) it is validated
//!   against (EXPERIMENTS.md §Perf).
//! * [`rounds`] — collectives decomposed into permutation rounds; each
//!   round is costed by link-load analysis. Scales to the full machine.
//! * [`analytic`] — closed-form link-load analysis for uniform patterns
//!   (all2all, bisection) at 84,992-endpoint scale.
//!
//! [`workload`] adds the closed-loop injection layer on top of the DES:
//! dependency DAGs of compute intervals and transfers whose releases are
//! triggered by predecessor completions ([`DesSim::run_dag`]), so
//! congestion in one collective round delays every later round — the
//! dynamics the open-loop tiers cannot express.
//!
//! [`arrivals`] is the open-loop *service* tier on the same executor:
//! Poisson/trace arrival sources batched into time-throttled streaming
//! windows, with windowed steady-state metrics (sustained throughput,
//! p50/p99/p999 completion latency, per-class backlog) at memory bounded
//! by peak concurrency — millions of arrivals over simulated hours.
//! Every execution mode is reachable through one builder,
//! [`DesSim::session`].
//!
//! [`analysis`] is the pre-execution workload verifier: structural
//! diagnostics (cycles, sentinel misuse, aliasing, collective byte
//! budgets) over any workload before it reaches an executor — the
//! paper's validate-before-scale posture applied to inputs.
//!
//! [`faults`] is deterministic mid-run fault injection: a time-ordered
//! [`FaultSchedule`] executed inside the DES event heap (`EV_FAULT`),
//! with reroute / retry-backoff / abort semantics for in-flight flows
//! crossing a link that goes down.
//!
//! [`degrade`] is the overload-control layer riding the open-loop tier:
//! per-[`RpcClass`] [`ServicePolicy`] (admission shedding, deadlines,
//! retry budgets, hedging) enforced by the streaming executor
//! (`EV_DEADLINE`/`EV_HEDGE`) and the arrival adapter.

pub mod analysis;
pub mod analytic;
pub mod arrivals;
pub mod degrade;
pub mod des;
pub mod faults;
pub mod load;
pub mod qos;
pub mod routing;
pub mod rounds;
pub mod workload;

pub use analysis::{
    check_collective_rounds, AnalysisReport, Collective, Diagnostic, Severity,
    WorkloadAnalyzer,
};
pub use arrivals::{
    run_open_loop, Arrival, ArrivalSource, PoissonArrivals, RpcClass,
    SteadyCollector, SteadyState, TraceArrivals,
};
pub use degrade::{brownout_policy, Admission, ClassPolicy, ServicePolicy};
pub use des::{
    DagResult, DesOpts, DesScratch, DesSession, DesSim, FlowOutcome,
    StreamResult, TimedFlow,
};
pub use faults::{FaultEvent, FaultKind, FaultPolicy, FaultSchedule};
pub use load::{LoadMap, SparseLoadMap};
pub use qos::TrafficClass;
pub use routing::Router;
pub use workload::{
    DagBuilder, DagKind, DagNode, DagWorkload, RoundSource, StreamNode,
    NO_KEY,
};

use crate::topology::Path;

/// Where a message buffer lives — decides the endpoint bandwidth path
/// (paper §5.1: host ~90 GB/s/socket vs GPU ~70 GB/s/socket) and the
/// RMA/HMEM behaviour (§5.3.5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BufLoc {
    Host,
    Gpu,
}

/// One simulated transfer.
#[derive(Debug, Clone)]
pub struct Flow {
    pub src_nic: u32,
    pub dst_nic: u32,
    pub bytes: u64,
    pub class: TrafficClass,
    pub buf: BufLoc,
    /// Ordered delivery (MPI envelopes): route pinned per destination
    /// (§3.1). Unordered bulk data may be sprayed per-packet.
    pub ordered: bool,
}

impl Flow {
    pub fn new(src_nic: u32, dst_nic: u32, bytes: u64) -> Self {
        Self {
            src_nic,
            dst_nic,
            bytes,
            class: TrafficClass::BestEffort,
            buf: BufLoc::Host,
            ordered: false,
        }
    }

    pub fn gpu(mut self) -> Self {
        self.buf = BufLoc::Gpu;
        self
    }

    pub fn class(mut self, class: TrafficClass) -> Self {
        self.class = class;
        self
    }

    pub fn ordered(mut self) -> Self {
        self.ordered = true;
        self
    }
}

/// Result of simulating a flow set: per-flow completion times.
#[derive(Debug, Clone)]
pub struct FlowTimes {
    pub per_flow: Vec<f64>,
    pub makespan: f64,
}

impl FlowTimes {
    pub fn from_vec(per_flow: Vec<f64>) -> Self {
        let makespan = per_flow.iter().cloned().fold(0.0, f64::max);
        Self { per_flow, makespan }
    }
}

/// A routed flow (path chosen by the adaptive router).
#[derive(Debug, Clone)]
pub struct RoutedFlow {
    pub flow: Flow,
    pub path: Path,
}
