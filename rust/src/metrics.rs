//! Small metrics/statistics helpers shared by benchmarks and the
//! reproduction harness (percentiles for GPCNet-style reporting, pretty
//! units, simple tables).

/// Percentile (nearest-rank) of a sample; `p` in [0, 100].
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(samples: &[f64]) -> f64 {
    samples.iter().sum::<f64>() / samples.len().max(1) as f64
}

/// Format bytes/s with the units the paper uses.
pub fn fmt_bw(bps: f64) -> String {
    if bps >= 1e15 {
        format!("{:.2} PB/s", bps / 1e15)
    } else if bps >= 1e12 {
        format!("{:.2} TB/s", bps / 1e12)
    } else if bps >= 1e9 {
        format!("{:.2} GB/s", bps / 1e9)
    } else if bps >= 1e6 {
        format!("{:.2} MB/s", bps / 1e6)
    } else {
        format!("{bps:.0} B/s")
    }
}

/// Format seconds with the units the paper uses (µs for latency plots).
pub fn fmt_time(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 90.0 {
        format!("{s:.2} s")
    } else {
        let h = (s / 3600.0) as u64;
        let m = ((s % 3600.0) / 60.0) as u64;
        let sec = s % 60.0;
        format!("{h}h {m:02}m {sec:02.0}s")
    }
}

/// Format flops/s.
pub fn fmt_flops(f: f64) -> String {
    if f >= 1e18 {
        format!("{:.3} EF/s", f / 1e18)
    } else if f >= 1e15 {
        format!("{:.2} PF/s", f / 1e15)
    } else if f >= 1e12 {
        format!("{:.2} TF/s", f / 1e12)
    } else {
        format!("{:.2} GF/s", f / 1e9)
    }
}

/// Render an aligned text table (header + rows).
pub fn table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut width: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate().take(ncol) {
            width[i] = width[i].max(c.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, width: &[usize]| -> String {
        let mut line = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!(" {:<w$} |", c, w = width[i]));
        }
        line.push('\n');
        line
    };
    out.push_str(&fmt_row(
        header.iter().map(|s| s.to_string()).collect(),
        &width,
    ));
    out.push_str(&fmt_row(
        width.iter().map(|w| "-".repeat(*w)).collect(),
        &width,
    ));
    for r in rows {
        out.push_str(&fmt_row(r.clone(), &width));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_bw(228.92e12), "228.92 TB/s");
        assert_eq!(fmt_bw(2.12e15), "2.12 PB/s");
        assert_eq!(fmt_time(3.1e-6), "3.10 us");
        assert_eq!(fmt_flops(1.012e18), "1.012 EF/s");
        // HPL runtime format (4h 21m)
        assert!(fmt_time(4.0 * 3600.0 + 21.0 * 60.0 + 54.0).starts_with("4h 21m"));
    }

    #[test]
    fn table_aligns() {
        let t = table(
            &["Nodes", "PF/s"],
            &[vec!["9234".into(), "1012".into()]],
        );
        assert!(t.contains("| Nodes |"));
        assert!(t.contains("| 9234  |"));
    }
}
