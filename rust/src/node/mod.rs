//! The Aurora compute node (paper §2, Fig 1): two SPR-HBM sockets, six PVC
//! GPUs, eight Cassini NICs (four per socket behind a PCIe switch), plus
//! the NUMA/binding logic of §3.8.4 and the per-endpoint data paths the
//! MPI measurements of §5.1 exercise.

use crate::config::AuroraConfig;

/// NUMA layout of §3.8.4:
/// node0 CPUs 0-51,104-155 with cxi0-cxi3; node1 CPUs 52-103,156-207 with
/// cxi4-cxi7 (52 physical cores + SMT siblings per socket).
#[derive(Debug, Clone)]
pub struct NumaMap {
    pub cores_per_socket: usize,
    pub sockets: usize,
    pub nics_per_node: usize,
}

impl NumaMap {
    pub fn new(cfg: &AuroraConfig) -> Self {
        Self {
            cores_per_socket: cfg.cores_per_socket,
            sockets: cfg.sockets_per_node,
            nics_per_node: cfg.nics_per_node,
        }
    }

    /// NUMA node of a CXI device: cxi0-3 -> 0, cxi4-7 -> 1 (§3.8.4).
    pub fn numa_of_nic(&self, nic_idx: usize) -> usize {
        nic_idx / (self.nics_per_node / self.sockets)
    }

    /// Physical core range of a socket, as the `lscpu` listing in §3.8.4
    /// shows it (physical cores only; SMT siblings are the +2*52 offset).
    pub fn cpus_of_socket(&self, socket: usize) -> (usize, usize) {
        let lo = socket * self.cores_per_socket;
        (lo, lo + self.cores_per_socket - 1)
    }

    /// The §3.8.4 NUMA listing line, e.g. "0-51,104-155" for socket 0.
    pub fn cpu_list_string(&self, socket: usize) -> String {
        let (lo, hi) = self.cpus_of_socket(socket);
        let smt_lo = lo + self.sockets * self.cores_per_socket;
        let smt_hi = hi + self.sockets * self.cores_per_socket;
        format!("{lo}-{hi},{smt_lo}-{smt_hi}")
    }

    /// cpu-bind list for `ppn` ranks: each rank is bound to cores on the
    /// socket its NIC hangs off (the mpiexec --cpu-bind the paper uses for
    /// all fabric validation; see §3.8.4 and argonne-lcf/pbs_utils).
    pub fn cpu_bind_list(&self, ppn: usize) -> Vec<String> {
        assert!(ppn >= 1);
        // ranks land on the socket of their NIC; hand out disjoint core
        // slices per socket in rank order
        let sockets: Vec<usize> = (0..ppn)
            .map(|r| self.numa_of_nic(self.nic_of_rank(r, ppn)))
            .collect();
        let per_socket: Vec<usize> = (0..self.sockets)
            .map(|s| sockets.iter().filter(|&&x| x == s).count())
            .collect();
        let mut next_idx = vec![0usize; self.sockets];
        sockets
            .iter()
            .map(|&socket| {
                let (lo, _) = self.cpus_of_socket(socket);
                let width = (self.cores_per_socket
                    / per_socket[socket].max(1))
                .max(1);
                let idx = next_idx[socket];
                next_idx[socket] += 1;
                let start = lo + (idx * width).min(self.cores_per_socket - 1);
                let end =
                    (start + width - 1).min(lo + self.cores_per_socket - 1);
                format!("{start}-{end}")
            })
            .collect()
    }

    /// Round-robin rank -> NIC assignment balanced across sockets, the
    /// "balancing the NIC assignments is a key" insight of §5.1 (Fig 13).
    pub fn nic_of_rank(&self, rank: usize, ppn: usize) -> usize {
        if ppn <= self.nics_per_node {
            // spread: alternate sockets first (ranks 0,1 -> cxi0,cxi4, ...)
            let per_socket = self.nics_per_node / self.sockets;
            let socket = rank % self.sockets;
            let idx = (rank / self.sockets) % per_socket;
            socket * per_socket + idx
        } else {
            rank % self.nics_per_node
        }
    }

    /// GPU for a rank (6 PVC per node, tile-level would double this).
    pub fn gpu_of_rank(&self, rank: usize, ppn: usize, gpus: usize) -> usize {
        if ppn <= gpus {
            rank % gpus
        } else {
            rank * gpus / ppn
        }
    }
}

/// Where a rank lives inside its node.
#[derive(Debug, Clone, Copy)]
pub struct RankLoc {
    pub node: usize,
    pub local_rank: usize,
    pub socket: usize,
    pub nic_idx: usize,
    pub gpu: usize,
}

/// Build placements for `nodes x ppn` ranks with the balanced binding.
pub fn place_ranks(cfg: &AuroraConfig, node_ids: &[usize], ppn: usize)
    -> Vec<RankLoc> {
    let numa = NumaMap::new(cfg);
    let mut out = Vec::with_capacity(node_ids.len() * ppn);
    for &node in node_ids {
        for lr in 0..ppn {
            let nic_idx = numa.nic_of_rank(lr, ppn);
            out.push(RankLoc {
                node,
                local_rank: lr,
                socket: numa.numa_of_nic(nic_idx),
                nic_idx,
                gpu: numa.gpu_of_rank(lr, ppn, cfg.gpus_per_node),
            });
        }
    }
    out
}

/// On-node data-path bandwidths (paper §2): used by intra-node MPI and the
/// GPU-direct path cost.
#[derive(Debug, Clone)]
pub struct NodePaths {
    pub xelink_bw: f64,
    pub pcie5_bw: f64,
    pub upi_bw: f64,
}

impl NodePaths {
    pub fn new(cfg: &AuroraConfig) -> Self {
        Self {
            xelink_bw: cfg.xelink_bw,
            pcie5_bw: cfg.pcie5_bw,
            upi_bw: 62.4e9, // 3x UPI 2.0 links between SPR sockets
        }
    }

    /// Intra-node transfer bandwidth between two ranks.
    pub fn intra_node_bw(&self, a: &RankLoc, b: &RankLoc, gpu_buf: bool) -> f64 {
        if gpu_buf {
            if a.gpu == b.gpu {
                // same device: HBM copy, effectively not a transfer
                1.0e12
            } else {
                // GPU-GPU over dedicated Xe-Link (all-to-all on node)
                self.xelink_bw
            }
        } else if a.socket == b.socket {
            // shared-memory copy through HBM/DDR
            90.0e9
        } else {
            self.upi_bw
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn numa() -> NumaMap {
        NumaMap::new(&AuroraConfig::aurora())
    }

    #[test]
    fn numa_listing_matches_paper() {
        // §3.8.4: NUMA node0 CPU(s): 0-51,104-155 ; node1: 52-103,156-207
        let n = numa();
        assert_eq!(n.cpu_list_string(0), "0-51,104-155");
        assert_eq!(n.cpu_list_string(1), "52-103,156-207");
    }

    #[test]
    fn cxi_numa_association() {
        // cxi0-cxi3 -> NUMA 0, cxi4-cxi7 -> NUMA 1
        let n = numa();
        for nic in 0..4 {
            assert_eq!(n.numa_of_nic(nic), 0);
        }
        for nic in 4..8 {
            assert_eq!(n.numa_of_nic(nic), 1);
        }
    }

    #[test]
    fn ppn8_uses_all_nics_once() {
        let n = numa();
        let mut used: Vec<usize> = (0..8).map(|r| n.nic_of_rank(r, 8)).collect();
        used.sort_unstable();
        assert_eq!(used, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn ppn16_shares_each_nic_twice() {
        let n = numa();
        let mut count = [0usize; 8];
        for r in 0..16 {
            count[n.nic_of_rank(r, 16)] += 1;
        }
        assert!(count.iter().all(|&c| c == 2), "{count:?}");
    }

    #[test]
    fn ppn4_balances_sockets() {
        // Fig 13: 4 ranks must land 2 per socket, not 4 on one
        let n = numa();
        let sockets: Vec<usize> =
            (0..4).map(|r| n.numa_of_nic(n.nic_of_rank(r, 4))).collect();
        assert_eq!(sockets.iter().filter(|&&s| s == 0).count(), 2);
    }

    #[test]
    fn cpu_bind_stays_on_nic_socket() {
        let n = numa();
        let binds = n.cpu_bind_list(8);
        assert_eq!(binds.len(), 8);
        for (rank, b) in binds.iter().enumerate() {
            let socket = n.numa_of_nic(n.nic_of_rank(rank, 8));
            let (lo, hi) = n.cpus_of_socket(socket);
            let start: usize = b.split('-').next().unwrap().parse().unwrap();
            assert!(start >= lo && start <= hi, "rank {rank} bind {b}");
        }
    }

    #[test]
    fn cpu_binds_do_not_overlap() {
        let n = numa();
        for ppn in [2usize, 4, 8, 12, 16] {
            let binds = n.cpu_bind_list(ppn);
            let mut seen = std::collections::HashSet::new();
            for b in &binds {
                assert!(seen.insert(b.clone()), "dup bind {b} at ppn {ppn}");
            }
        }
    }

    #[test]
    fn placement_covers_all_ranks() {
        let cfg = AuroraConfig::tiny();
        let locs = place_ranks(&cfg, &[0, 1, 2], 12);
        assert_eq!(locs.len(), 36);
        assert!(locs.iter().all(|l| l.nic_idx < 8 && l.gpu < 6));
    }

    #[test]
    fn intra_node_paths() {
        let cfg = AuroraConfig::aurora();
        let p = NodePaths::new(&cfg);
        let a = RankLoc { node: 0, local_rank: 0, socket: 0, nic_idx: 0, gpu: 0 };
        let b = RankLoc { node: 0, local_rank: 1, socket: 1, nic_idx: 4, gpu: 3 };
        assert_eq!(p.intra_node_bw(&a, &b, true), cfg.xelink_bw);
        assert!(p.intra_node_bw(&a, &b, false) < 90.0e9 + 1.0);
    }
}
