//! One-sided communication (paper §5.3.5, Tables 4-6).
//!
//! The PVC GPU "has been found to be unable to provide RMA support in
//! hardware and instead the needed functionality has been implemented in
//! software". This module models that software path:
//!
//! * MPI_Get / MPI_Put per-op costs calibrated from the paper's Tables 5/6
//!   (see `config`): Get is ~10x cheaper than Put; HMEM
//!   (MPIR_CVAR_CH4_OFI_ENABLE_HMEM) speeds Get ~10x and Put ~2x.
//! * A finite internal buffer: the application MUST call MPI_Win_fence
//!   every `rma_buffer_ops` operations (100 for Put without HMEM) or the
//!   phase fails — exactly the "communication failure" the paper hit.
//! * Inter-node one-sided ops pay the sub-communicator overhead that made
//!   the 9x16 configuration an order of magnitude slower (Table 5 row 4).
//!
//! Functional windows hold real `f64` data so FMM-style access patterns
//! can be validated end to end.

use super::{Comm, World};
use anyhow::{bail, Result};
use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RmaKind {
    Get,
    Put,
}

/// One one-sided operation in a phase.
#[derive(Debug, Clone, Copy)]
pub struct RmaOp {
    pub kind: RmaKind,
    pub origin: usize,
    pub target: usize,
    /// Offset (elements) into the target window.
    pub offset: usize,
    /// Elements (f64) transferred.
    pub len: usize,
}

/// An RMA window: per-rank exposed memory + epoch bookkeeping.
pub struct WindowSim {
    /// Exposed local memory per communicator rank (functional mode).
    pub data: Vec<Vec<f64>>,
    /// Ops absorbed by each rank's software buffer since the last fence.
    since_fence: Vec<usize>,
    pub hmem: bool,
    /// Total fences executed.
    pub fences: usize,
}

impl WindowSim {
    /// Create a window of `win_len` f64 elements on each of `n` ranks.
    pub fn new(n: usize, win_len: usize, hmem: bool) -> Self {
        Self {
            data: vec![vec![0.0; win_len]; n],
            since_fence: vec![0; n],
            hmem,
            fences: 0,
        }
    }

    fn buffer_capacity(&self, w: &World, kind: RmaKind) -> usize {
        match (kind, self.hmem) {
            (RmaKind::Put, false) => w.cfg().rma_buffer_ops_put_nohmem,
            _ => w.cfg().rma_buffer_ops,
        }
    }

    fn op_engine_cost(&self, w: &World, kind: RmaKind) -> f64 {
        let c = w.cfg();
        match (kind, self.hmem) {
            (RmaKind::Get, true) => c.rma_get_hmem_op,
            (RmaKind::Get, false) => c.rma_get_nohmem_op,
            (RmaKind::Put, true) => c.rma_put_hmem_op,
            (RmaKind::Put, false) => c.rma_put_nohmem_op,
        }
    }

    /// Execute a phase of one-sided ops issued concurrently by all
    /// origins, moving real data and returning the phase time.
    ///
    /// Fails (like the real code) if any rank's software buffer would
    /// overflow — callers must fence often enough.
    pub fn run_phase(&mut self, w: &mut World, comm: &Comm, ops: &[RmaOp])
        -> Result<f64> {
        // Epoch semantics: all reads in a fence epoch observe the window
        // state at epoch start (MPI one-sided separate-memory model).
        let snapshot: Vec<Vec<f64>> = self.data.clone();
        // --- functional data movement + buffer accounting ---
        for op in ops {
            let cap = self.buffer_capacity(w, op.kind);
            let absorber = match op.kind {
                // Get buffers at the origin (result staging); Put at target
                RmaKind::Get => op.origin,
                RmaKind::Put => op.target,
            };
            self.since_fence[absorber] += 1;
            if self.since_fence[absorber] > cap {
                bail!(
                    "software RMA buffer overflow on rank {absorber} \
                     ({} ops since fence, capacity {cap}) — call \
                     MPI_Win_fence more often (paper §5.3.5)",
                    self.since_fence[absorber]
                );
            }
            match op.kind {
                RmaKind::Get => {
                    self.data[op.origin][op.offset..op.offset + op.len]
                        .copy_from_slice(
                            &snapshot[op.target]
                                [op.offset..op.offset + op.len],
                        );
                }
                RmaKind::Put => {
                    self.data[op.target][op.offset..op.offset + op.len]
                        .copy_from_slice(
                            &snapshot[op.origin]
                                [op.offset..op.offset + op.len],
                        );
                }
            }
        }

        // --- timing: per-node engine load, per-origin-rank serialized
        //     load (Get w/o HMEM), wire time for inter-node ops ---
        let mut node_engine: HashMap<usize, f64> = HashMap::new();
        let mut rank_serial: HashMap<usize, f64> = HashMap::new();
        let mut wire_bytes: HashMap<(usize, usize), u64> = HashMap::new();
        for op in ops {
            let (orank, trank) = (comm.ranks[op.origin], comm.ranks[op.target]);
            let onode = w.placements[orank].node;
            let tnode = w.placements[trank].node;
            let mut cost = self.op_engine_cost(w, op.kind);
            if onode != tnode {
                cost += w.cfg().rma_internode_overhead;
                *wire_bytes.entry((orank, trank)).or_insert(0) +=
                    (op.len * 8) as u64;
            }
            if op.kind == RmaKind::Get && !self.hmem {
                // host-staged Get serializes at the origin rank
                *rank_serial.entry(orank).or_insert(0.0) += cost;
            } else {
                // shared software progress engine at the servicing node
                let engine_node =
                    if op.kind == RmaKind::Get { tnode } else { tnode };
                *node_engine.entry(engine_node).or_insert(0.0) += cost;
            }
        }
        let engine_t = node_engine.values().cloned().fold(0.0, f64::max);
        let serial_t = rank_serial.values().cloned().fold(0.0, f64::max);
        // wire time: one concurrent round of the aggregated transfers
        let wire_t = if wire_bytes.is_empty() {
            0.0
        } else {
            let msgs: Vec<(usize, usize, u64)> = wire_bytes
                .iter()
                .map(|(&(s, d), &b)| (s, d, b))
                .collect();
            // the duration is consumed below, so the round must price
            // immediately even if exchange supersteps are being staged
            w.exchange_now(&msgs)
        };
        let t = engine_t.max(serial_t) + wire_t;
        w.sync_clocks(comm, t);
        Ok(t)
    }

    /// MPI_Win_fence: flush the software buffers (a synchronizing op).
    pub fn fence(&mut self, w: &mut World, comm: &Comm) -> f64 {
        for c in &mut self.since_fence {
            *c = 0;
        }
        self.fences += 1;
        super::coll::barrier(w, comm)
    }
}

/// Run `ops` split into fence epochs of `fence_every` ops — the usage
/// pattern the paper converged on (fence every 2000 calls; 100 for Put
/// without HMEM). Returns total time.
pub fn run_with_fences(w: &mut World, comm: &Comm, win: &mut WindowSim,
                       ops: &[RmaOp], fence_every: usize) -> Result<f64> {
    let mut t = 0.0;
    for chunk in ops.chunks(fence_every.max(1)) {
        t += win.run_phase(w, comm, chunk)?;
        t += win.fence(w, comm);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::machine::Machine;

    fn setup(nodes: usize, ppn: usize) -> (Machine, Comm) {
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let comm = Comm::world(nodes * ppn);
        (m, comm)
    }

    fn ops(kind: RmaKind, n_ranks: usize, per_rank: usize, len: usize)
        -> Vec<RmaOp> {
        let mut v = Vec::new();
        for o in 0..n_ranks {
            for k in 0..per_rank {
                v.push(RmaOp {
                    kind,
                    origin: o,
                    target: (o + 1 + k) % n_ranks,
                    offset: 0,
                    len,
                });
            }
        }
        v
    }

    #[test]
    fn get_moves_data() {
        let (m, comm) = setup(1, 4);
        let mut w = World::new(&m.topo, m.place_job(0, 1, 4));
        let mut win = WindowSim::new(4, 8, true);
        win.data[2] = vec![7.0; 8];
        let op = RmaOp { kind: RmaKind::Get, origin: 0, target: 2,
                         offset: 0, len: 8 };
        win.run_phase(&mut w, &comm, &[op]).unwrap();
        assert_eq!(win.data[0], vec![7.0; 8]);
    }

    #[test]
    fn put_moves_data() {
        let (m, comm) = setup(1, 4);
        let mut w = World::new(&m.topo, m.place_job(0, 1, 4));
        let mut win = WindowSim::new(4, 4, true);
        win.data[1] = vec![3.0; 4];
        let op = RmaOp { kind: RmaKind::Put, origin: 1, target: 3,
                         offset: 0, len: 4 };
        win.run_phase(&mut w, &comm, &[op]).unwrap();
        assert_eq!(win.data[3], vec![3.0; 4]);
    }

    #[test]
    fn get_order_of_magnitude_faster_than_put() {
        // Tables 5 vs 6 headline
        let (m, comm) = setup(1, 8);
        let o = ops(RmaKind::Get, 8, 100, 16);
        let mut w = World::new(&m.topo, m.place_job(0, 1, 8));
        let mut win = WindowSim::new(8, 16, true);
        let t_get = win.run_phase(&mut w, &comm, &o).unwrap();
        let o = ops(RmaKind::Put, 8, 100, 16);
        let mut w = World::new(&m.topo, m.place_job(0, 1, 8));
        let mut win = WindowSim::new(8, 16, true);
        let t_put = win.run_phase(&mut w, &comm, &o).unwrap();
        assert!(t_put > 8.0 * t_get, "get {t_get} put {t_put}");
    }

    #[test]
    fn hmem_speeds_up_get_by_order_of_magnitude() {
        let (m, comm) = setup(1, 8);
        let o = ops(RmaKind::Get, 8, 100, 16);
        let mut w = World::new(&m.topo, m.place_job(0, 1, 8));
        let t_hmem = WindowSim::new(8, 16, true)
            .run_phase(&mut w, &comm, &o).unwrap();
        let mut w = World::new(&m.topo, m.place_job(0, 1, 8));
        let t_plain = WindowSim::new(8, 16, false)
            .run_phase(&mut w, &comm, &o).unwrap();
        assert!(t_plain > 8.0 * t_hmem, "hmem {t_hmem} plain {t_plain}");
    }

    #[test]
    fn buffer_overflow_without_fence() {
        let (m, comm) = setup(1, 4);
        let mut w = World::new(&m.topo, m.place_job(0, 1, 4));
        let mut win = WindowSim::new(4, 4, true);
        let cap = w.cfg().rma_buffer_ops;
        // every op targets rank 1's buffer via Put
        let many: Vec<RmaOp> = (0..cap + 1)
            .map(|_| RmaOp { kind: RmaKind::Put, origin: 0, target: 1,
                             offset: 0, len: 1 })
            .collect();
        assert!(win.run_phase(&mut w, &comm, &many).is_err());
    }

    #[test]
    fn put_without_hmem_overflows_much_earlier() {
        // paper: fence every 100 required for Put w/o HMEM
        let (m, comm) = setup(1, 4);
        let mut w = World::new(&m.topo, m.place_job(0, 1, 4));
        let mut win = WindowSim::new(4, 4, false);
        let many: Vec<RmaOp> = (0..150)
            .map(|_| RmaOp { kind: RmaKind::Put, origin: 0, target: 1,
                             offset: 0, len: 1 })
            .collect();
        assert!(win.run_phase(&mut w, &comm, &many).is_err());
        // with fences every 100 it succeeds
        let mut w = World::new(&m.topo, m.place_job(0, 1, 4));
        let mut win = WindowSim::new(4, 4, false);
        assert!(run_with_fences(&mut w, &comm, &mut win, &many, 100).is_ok());
    }

    #[test]
    fn fences_reset_buffers() {
        let (m, comm) = setup(1, 4);
        let mut w = World::new(&m.topo, m.place_job(0, 1, 4));
        let mut win = WindowSim::new(4, 4, true);
        let op = RmaOp { kind: RmaKind::Put, origin: 0, target: 1,
                         offset: 0, len: 1 };
        for _ in 0..3 {
            win.run_phase(&mut w, &comm, &vec![op; 1500]).unwrap();
            win.fence(&mut w, &comm);
        }
        assert_eq!(win.fences, 3);
    }

    #[test]
    fn internode_ops_cost_more() {
        let (m, _) = setup(2, 8);
        // 16 ranks over 2 nodes
        let comm = Comm::world(16);
        let o_intra = ops(RmaKind::Get, 8, 50, 16); // ranks 0-7 (node 0)
        let mut w = World::new(&m.topo, m.place_job(0, 2, 8));
        let mut win = WindowSim::new(16, 16, true);
        let t_intra = win.run_phase(&mut w, &comm, &o_intra).unwrap();
        // same op count but to node-1 targets
        let o_inter: Vec<RmaOp> = o_intra
            .iter()
            .map(|o| RmaOp { target: o.target + 8, ..*o })
            .collect();
        let mut w = World::new(&m.topo, m.place_job(0, 2, 8));
        let mut win = WindowSim::new(16, 16, true);
        let t_inter = win.run_phase(&mut w, &comm, &o_inter).unwrap();
        assert!(t_inter > 5.0 * t_intra, "intra {t_intra} inter {t_inter}");
    }
}
