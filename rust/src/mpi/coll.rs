//! Collective algorithms (paper §5.1, Fig 14).
//!
//! MPICH on Aurora switches MPI_Allreduce between a latency-optimized
//! tree (recursive doubling) for small messages and a bandwidth-optimized
//! ring (reduce-scatter + allgather) for large ones — "a switch from a
//! ring algorithm to a tree algorithm is clearly seen on the curves"
//! (Fig 14). Both are implemented over the fabric tiers, plus pairwise
//! all2all, binomial broadcast, barrier, allgather and reduce-scatter.
//!
//! Round structure is costed exactly; rounds that repeat the same
//! permutation (ring steps) are evaluated once and scaled, which is what
//! lets the Fig 14 sweep run to 2,048 nodes in milliseconds.

use super::{Comm, FabricTier, World};
use crate::fabric::des::DesSim;
use crate::fabric::workload::{DagBuilder, DagWorkload, StreamNode};
use crate::fabric::{RoutedFlow, TrafficClass};

/// Cost one communication round without advancing clocks (the collective
/// functions accumulate round costs and sync once).
fn round_cost(w: &mut World, msgs: &[(usize, usize, u64)]) -> f64 {
    if msgs.is_empty() {
        return 0.0;
    }
    let mut routed = Vec::with_capacity(msgs.len());
    let mut intra_max = 0.0f64;
    for &(s, d, b) in msgs {
        let (pa, pb) = (w.placements[s], w.placements[d]);
        if pa.node == pb.node {
            let t = crate::mpi::intra_node_time(
                &crate::node::NodePaths::new(w.cfg()),
                w.cfg(),
                &pa,
                &pb,
                matches!(w.buf, crate::fabric::BufLoc::Gpu),
                b,
            );
            intra_max = intra_max.max(t);
        } else {
            let f = crate::fabric::Flow {
                src_nic: w.nics[s],
                dst_nic: w.nics[d],
                bytes: b,
                class: w.class,
                buf: w.buf,
                ordered: false,
            };
            let path = w.router.route(&f);
            w.counters.record_send_class(w.nics[s], b, f.class);
            routed.push(crate::fabric::RoutedFlow { flow: f, path });
        }
    }
    let fabric_max = if routed.is_empty() {
        0.0
    } else {
        w.cost_model().eval_round(&routed).makespan
    };
    intra_max.max(fabric_max)
}

/// Largest power of two <= n.
fn pow2_floor(n: usize) -> usize {
    let mut p = 1;
    while p * 2 <= n {
        p *= 2;
    }
    p
}

// ----------------------------------------------------------- DES tier

/// Assemble a closed-loop dependency DAG from round triples: a message
/// in round k is released once every round-(k-1) node touching its
/// source rank is done (its own send plus the receives it folds in),
/// intra-node messages become fixed-duration compute nodes, and fabric
/// messages are routed exactly like the analytic tier routes them. The
/// DAG runs on [`DesSim::run_dag`], so cross-round queueing dynamics —
/// invisible to [`round_cost`]'s independent per-round pricing — delay
/// later rounds (`FabricTier::Des`).
pub fn rounds_dag(
    w: &mut World,
    rounds: &[Vec<(usize, usize, u64)>],
) -> DagWorkload {
    // DagBuilder keyed by world rank: frontier/round-commit semantics
    // live in one place (fabric::workload), this function only adds the
    // placement-aware routing and counter accounting
    let mut b = DagBuilder::new();
    for round in rounds {
        for &(s, d, bytes) in round {
            let (pa, pb) = (w.placements[s], w.placements[d]);
            if pa.node == pb.node {
                b.compute_staged(
                    s as u32,
                    d as u32,
                    w.intra_node_time(&pa, &pb, bytes),
                );
            } else {
                let f = crate::fabric::Flow {
                    src_nic: w.nics[s],
                    dst_nic: w.nics[d],
                    bytes,
                    class: w.class,
                    buf: w.buf,
                    ordered: false,
                };
                let path = w.router.route(&f);
                w.counters.record_send_class(w.nics[s], bytes, f.class);
                b.xfer(s as u32, d as u32, RoutedFlow { flow: f, path });
            }
        }
        b.end_round();
    }
    b.finish()
}

/// Execute lazily generated world-rank round triples closed-loop on the
/// **streaming** DES executor ([`DesSim::run_stream`]): rounds
/// materialize, route and retire incrementally, so Fig 14-scale
/// collectives (2,048+ endpoints, O(P^2) total messages) run
/// dependency-released without ever holding the full round DAG in
/// memory. Intra-node messages become fixed-duration nodes exactly as in
/// [`rounds_dag`]. Returns the makespan.
fn stream_rounds<G>(w: &mut World, mut gen: G) -> f64
where
    G: FnMut(usize) -> Option<Vec<(usize, usize, u64)>>,
{
    let topo = w.topo;
    let opts = w.des_opts.clone();
    let sim = DesSim::new(topo, opts);
    // disjoint field borrows: the round source routes/records through
    // the router and counters while the executor owns the scratch
    let World {
        placements,
        nics,
        router,
        counters,
        scratch,
        node_paths,
        buf,
        class,
        ..
    } = w;
    let buf = *buf;
    let class = *class;
    let gpu = matches!(buf, crate::fabric::BufLoc::Gpu);
    let mut k = 0usize;
    let mut src = || -> Option<Vec<StreamNode>> {
        let triples = gen(k)?;
        k += 1;
        Some(
            triples
                .into_iter()
                .map(|(s, d, bytes)| {
                    let (pa, pb) = (placements[s], placements[d]);
                    if pa.node == pb.node {
                        StreamNode::Compute {
                            a: s as u32,
                            b: d as u32,
                            dt: crate::mpi::intra_node_time(
                                node_paths, &topo.cfg, &pa, &pb, gpu, bytes,
                            ),
                            start: 0.0,
                        }
                    } else {
                        let f = crate::fabric::Flow {
                            src_nic: nics[s],
                            dst_nic: nics[d],
                            bytes,
                            class,
                            buf,
                            ordered: false,
                        };
                        let path = router.route(&f);
                        counters.record_send_class(nics[s], bytes, f.class);
                        StreamNode::Xfer {
                            a: s as u32,
                            b: d as u32,
                            rf: RoutedFlow { flow: f, path },
                            start: 0.0,
                        }
                    }
                })
                .collect(),
        )
    };
    sim.run_stream_with(&mut src, scratch).makespan
}

/// The trivial (size <= 1) communicator case: nothing to communicate,
/// but on the Des tier a collective is still a **flush point** — pending
/// staged supersteps price now, so the documented flush contract holds
/// for every collective at every comm size (not just the ones whose
/// round lists happen to be non-empty).
fn trivial_collective(w: &mut World, comm: &Comm) -> f64 {
    if w.staging() {
        let t = w.stage_rounds_and_flush(&[]);
        w.sync_clocks(comm, 0.0);
        t
    } else {
        0.0
    }
}

/// The Des-tier dispatch shared by every collective. While superstep
/// staging is active the rounds are materialized and flushed together
/// with the pending exchanges as ONE dependency DAG (collectives are
/// flush points; note this path holds the full round list — see
/// EXPERIMENTS.md §Supersteps for the memory caveat vs streaming);
/// otherwise the rounds stream on the windowed executor and the return
/// value is the collective's own makespan. **While staging, the return
/// value is the flushed superstep's wall span — pending staged
/// exchanges included** — the pending work and the collective price as
/// one inseparable dependency DAG, so a per-collective time does not
/// exist there; callers timing a collective in isolation should invoke
/// it outside a superstep (or `World::flush_steps` first). Either way
/// the communicator's clocks are synchronized — keeping the flush/sync
/// protocol in exactly one place.
fn des_collective<G>(w: &mut World, comm: &Comm, mut gen: G) -> f64
where
    G: FnMut(usize) -> Option<Vec<(usize, usize, u64)>>,
{
    if w.staging() {
        let rounds: Vec<_> = (0..).map_while(&mut gen).collect();
        let t = w.stage_rounds_and_flush(&rounds);
        w.sync_clocks(comm, 0.0);
        t
    } else {
        let t = stream_rounds(w, gen);
        w.sync_clocks(comm, t);
        t
    }
}

/// Round structure of the recursive-doubling allreduce — remainder
/// fold-in, log2(P) exchange rounds, fold-out — as world-rank triples.
pub fn allreduce_tree_rounds(
    comm: &Comm,
    bytes: u64,
) -> Vec<Vec<(usize, usize, u64)>> {
    let p = comm.size();
    let mut rounds = Vec::new();
    if p <= 1 {
        return rounds;
    }
    let p2 = pow2_floor(p);
    let rem = p - p2;
    if rem > 0 {
        rounds.push(
            (0..rem)
                .map(|i| (comm.ranks[p2 + i], comm.ranks[i], bytes))
                .collect(),
        );
    }
    let mut dist = 1;
    while dist < p2 {
        rounds.push(
            (0..p2)
                .map(|i| (comm.ranks[i], comm.ranks[i ^ dist], bytes))
                .collect(),
        );
        dist *= 2;
    }
    if rem > 0 {
        rounds.push(
            (0..rem)
                .map(|i| (comm.ranks[i], comm.ranks[p2 + i], bytes))
                .collect(),
        );
    }
    rounds
}

/// The shift-by-one ring round shared by the ring allreduce, allgather
/// and reduce-scatter lazy generators: round `k` of `total` identical
/// permutation rounds of `chunk` bytes per neighbour, `None` past the
/// end. The ring *shape* lives here once; the callers differ only in
/// chunk size and round count.
fn ring_shift_round_k(
    comm: &Comm,
    chunk: u64,
    total: usize,
    k: usize,
) -> Option<Vec<(usize, usize, u64)>> {
    let p = comm.size();
    if p <= 1 || k >= total {
        return None;
    }
    Some(
        (0..p)
            .map(|i| (comm.ranks[i], comm.ranks[(i + 1) % p], chunk))
            .collect(),
    )
}

/// Round `k` (0-based) of the ring allreduce — 2(P-1) shift-by-one
/// rounds of bytes/P chunks — generated lazily so Fig 14-scale streams
/// never materialize the O(P^2) triple list. `None` past the last round.
pub fn allreduce_ring_round_k(
    comm: &Comm,
    bytes: u64,
    k: usize,
) -> Option<Vec<(usize, usize, u64)>> {
    let p = comm.size();
    let chunk = (bytes / p.max(1) as u64).max(1);
    ring_shift_round_k(comm, chunk, 2 * p.saturating_sub(1), k)
}

/// Round structure of the ring allreduce: 2(P-1) shift-by-one rounds of
/// bytes/P chunks (materialized; [`allreduce_ring_round_k`] is the lazy
/// form the streaming executor consumes).
pub fn allreduce_ring_rounds(
    comm: &Comm,
    bytes: u64,
) -> Vec<Vec<(usize, usize, u64)>> {
    (0..)
        .map_while(|k| allreduce_ring_round_k(comm, bytes, k))
        .collect()
}

/// Round `k` of the pairwise-exchange all2all (rotation shift k+1 of
/// P-1), generated lazily for the streaming executor.
pub fn alltoall_round_k(
    comm: &Comm,
    bytes_per_pair: u64,
    k: usize,
) -> Option<Vec<(usize, usize, u64)>> {
    let p = comm.size();
    if p <= 1 || k >= p - 1 {
        return None;
    }
    let shift = k + 1;
    Some(
        (0..p)
            .map(|i| {
                (comm.ranks[i], comm.ranks[(i + shift) % p], bytes_per_pair)
            })
            .collect(),
    )
}

/// Round structure of the pairwise-exchange all2all: P-1 rotation
/// rounds (no sampling — the closed-loop tier executes every round).
pub fn alltoall_rounds(
    comm: &Comm,
    bytes_per_pair: u64,
) -> Vec<Vec<(usize, usize, u64)>> {
    (0..)
        .map_while(|k| alltoall_round_k(comm, bytes_per_pair, k))
        .collect()
}

/// Round structure of the binomial-tree broadcast: ceil(log2(P))
/// doubling rounds from `root_idx` — round r's senders were all touched
/// in round r-1, so the rounds chain correctly under dependency release.
pub fn bcast_rounds(
    comm: &Comm,
    root_idx: usize,
    bytes: u64,
) -> Vec<Vec<(usize, usize, u64)>> {
    let p = comm.size();
    let mut rounds = Vec::new();
    if p <= 1 {
        return rounds;
    }
    let mut reach = 1usize;
    while reach < p {
        rounds.push(
            (0..reach.min(p - reach))
                .map(|i| {
                    let src = (root_idx + i) % p;
                    let dst = (root_idx + i + reach) % p;
                    (comm.ranks[src], comm.ranks[dst], bytes)
                })
                .collect(),
        );
        reach *= 2;
    }
    rounds
}

/// Round `k` of the ring allgather: P-1 shift-by-one rounds forwarding
/// the most recently received contribution (lazy form).
pub fn allgather_round_k(
    comm: &Comm,
    bytes_per_rank: u64,
    k: usize,
) -> Option<Vec<(usize, usize, u64)>> {
    ring_shift_round_k(
        comm,
        bytes_per_rank,
        comm.size().saturating_sub(1),
        k,
    )
}

/// Round structure of the ring allgather (materialized form).
pub fn allgather_rounds(
    comm: &Comm,
    bytes_per_rank: u64,
) -> Vec<Vec<(usize, usize, u64)>> {
    (0..)
        .map_while(|k| allgather_round_k(comm, bytes_per_rank, k))
        .collect()
}

/// Round `k` of the ring reduce-scatter: P-1 shift-by-one rounds of
/// bytes/P chunks (lazy form).
pub fn reduce_scatter_round_k(
    comm: &Comm,
    bytes: u64,
    k: usize,
) -> Option<Vec<(usize, usize, u64)>> {
    let p = comm.size();
    let chunk = (bytes / p.max(1) as u64).max(1);
    ring_shift_round_k(comm, chunk, p.saturating_sub(1), k)
}

/// Round structure of the ring reduce-scatter (materialized form).
pub fn reduce_scatter_rounds(
    comm: &Comm,
    bytes: u64,
) -> Vec<Vec<(usize, usize, u64)>> {
    (0..)
        .map_while(|k| reduce_scatter_round_k(comm, bytes, k))
        .collect()
}

// ------------------------------------------------------------------ allreduce

/// MPI_Allreduce timing for `bytes` per rank. Picks tree vs ring by the
/// configured cutoff, exactly like the curves of Fig 14. On
/// `FabricTier::Des` the chosen algorithm's rounds run closed-loop and
/// **streamed** ([`DesSim::run_stream`]) — at most a window of rounds is
/// live at once, so the Fig 14 sweep reaches 2,048 nodes without the
/// O(P^2) DAG; while superstep staging is active the rounds instead join
/// the staged exchange DAG and the whole superstep flushes as one
/// dependency-released run — the returned time is then the flushed
/// span, pending exchanges included (see [`des_collective`]).
pub fn allreduce(w: &mut World, comm: &Comm, bytes: u64) -> f64 {
    let tree = bytes <= w.cfg().allreduce_tree_cutoff;
    match w.tier {
        FabricTier::Des => {
            if tree {
                let rounds = allreduce_tree_rounds(comm, bytes);
                des_collective(w, comm, |k| rounds.get(k).cloned())
            } else {
                des_collective(w, comm, |k| {
                    allreduce_ring_round_k(comm, bytes, k)
                })
            }
        }
        FabricTier::Analytic => {
            let t = if tree {
                allreduce_tree_time(w, comm, bytes)
            } else {
                allreduce_ring_time(w, comm, bytes)
            };
            w.sync_clocks(comm, t);
            t
        }
    }
}

/// Recursive-doubling allreduce: log2(P) rounds of full-size exchanges
/// (+ fold rounds for non-power-of-two communicators).
pub fn allreduce_tree_time(w: &mut World, comm: &Comm, bytes: u64) -> f64 {
    let p = comm.size();
    if p <= 1 {
        return 0.0;
    }
    let p2 = pow2_floor(p);
    let rem = p - p2;
    let mut t = 0.0;
    // fold the remainder in (and back out at the end)
    if rem > 0 {
        let msgs: Vec<_> = (0..rem)
            .map(|i| (comm.ranks[p2 + i], comm.ranks[i], bytes))
            .collect();
        t += 2.0 * round_cost(w, &msgs);
    }
    let mut dist = 1;
    while dist < p2 {
        let msgs: Vec<_> = (0..p2)
            .map(|i| (comm.ranks[i], comm.ranks[i ^ dist], bytes))
            .collect();
        t += round_cost(w, &msgs);
        dist *= 2;
    }
    t
}

/// Ring (reduce-scatter + allgather) allreduce: 2(P-1) neighbour rounds of
/// bytes/P chunks. Every round is the same shift-by-one permutation, so we
/// cost one round and scale.
pub fn allreduce_ring_time(w: &mut World, comm: &Comm, bytes: u64) -> f64 {
    let p = comm.size();
    if p <= 1 {
        return 0.0;
    }
    let chunk = (bytes / p as u64).max(1);
    let msgs: Vec<_> = (0..p)
        .map(|i| (comm.ranks[i], comm.ranks[(i + 1) % p], chunk))
        .collect();
    let per_round = round_cost(w, &msgs);
    2.0 * (p - 1) as f64 * per_round
}

/// Functional allreduce (sum): reduces real data across the communicator
/// and returns the operation time.
pub fn allreduce_data(w: &mut World, comm: &Comm, bufs: &mut [Vec<f64>])
    -> f64 {
    assert_eq!(bufs.len(), comm.size());
    let n = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == n), "mismatched buffers");
    let mut sum = vec![0.0f64; n];
    for b in bufs.iter() {
        for (s, v) in sum.iter_mut().zip(b) {
            *s += v;
        }
    }
    for b in bufs.iter_mut() {
        b.copy_from_slice(&sum);
    }
    allreduce(w, comm, (n * 8) as u64)
}

// ------------------------------------------------------------------ all2all

/// Pairwise-exchange all2all: P-1 rotation rounds of `bytes` per pair.
/// On the analytic tier a sample of rounds is costed and scaled (the
/// rotation rounds are statistically identical); on `FabricTier::Des`
/// every round executes closed-loop, streamed round by round.
pub fn alltoall(w: &mut World, comm: &Comm, bytes_per_pair: u64) -> f64 {
    let p = comm.size();
    if p <= 1 {
        return trivial_collective(w, comm);
    }
    match w.tier {
        FabricTier::Des => des_collective(w, comm, |k| {
            alltoall_round_k(comm, bytes_per_pair, k)
        }),
        FabricTier::Analytic => {
            let rounds = p - 1;
            let sample = rounds.min(24);
            let mut t_sample = 0.0;
            for k in 1..=sample {
                // stride pattern that covers near and far partners
                let shift = 1 + (k - 1) * rounds / sample;
                let msgs: Vec<_> = (0..p)
                    .map(|i| {
                        (comm.ranks[i], comm.ranks[(i + shift) % p],
                         bytes_per_pair)
                    })
                    .collect();
                t_sample += round_cost(w, &msgs);
            }
            let t = t_sample * rounds as f64 / sample as f64;
            w.sync_clocks(comm, t);
            t
        }
    }
}

/// Functional all2all on real data: `bufs[i][j]` is rank i's block for
/// rank j; returns (received blocks, time).
pub fn alltoall_data(w: &mut World, comm: &Comm, bufs: &[Vec<Vec<f64>>])
    -> (Vec<Vec<Vec<f64>>>, f64) {
    let p = comm.size();
    assert_eq!(bufs.len(), p);
    let bytes = (bufs[0][0].len() * 8) as u64;
    let mut recv = vec![vec![Vec::new(); p]; p];
    for i in 0..p {
        assert_eq!(bufs[i].len(), p);
        for j in 0..p {
            recv[j][i] = bufs[i][j].clone();
        }
    }
    let t = alltoall(w, comm, bytes);
    (recv, t)
}

// ------------------------------------------------------------------ others

/// Binomial-tree broadcast. The tier dispatch is an exhaustive `match`:
/// a future `FabricTier` variant fails to compile here instead of
/// silently falling back to analytic round pricing.
pub fn bcast(w: &mut World, comm: &Comm, root_idx: usize, bytes: u64) -> f64 {
    let p = comm.size();
    if p <= 1 {
        return trivial_collective(w, comm);
    }
    match w.tier {
        FabricTier::Des => {
            let rounds = bcast_rounds(comm, root_idx, bytes);
            des_collective(w, comm, |k| rounds.get(k).cloned())
        }
        FabricTier::Analytic => {
            let mut t = 0.0;
            for round in bcast_rounds(comm, root_idx, bytes) {
                t += round_cost(w, &round);
            }
            w.sync_clocks(comm, t);
            t
        }
    }
}

/// Barrier: recursive doubling with 8-byte tokens on the **LowLatency**
/// traffic class — §3.1: "low latency operations ... could run in a
/// high-priority traffic class". The world's class is swapped for the
/// barrier rounds and restored afterwards, so barrier flows are recorded
/// (and priced, on tiers that differentiate classes) as LowLatency while
/// surrounding traffic keeps its own class.
pub fn barrier(w: &mut World, comm: &Comm) -> f64 {
    /// Restores the world's traffic class even if pricing panics
    /// (a caught unwind must not leave the world stuck on LowLatency).
    struct ClassGuard<'a, 'w> {
        w: &'a mut World<'w>,
        prev: TrafficClass,
    }
    impl Drop for ClassGuard<'_, '_> {
        fn drop(&mut self) {
            self.w.class = self.prev;
        }
    }
    let prev = w.class;
    w.class = TrafficClass::LowLatency;
    let guard = ClassGuard { w, prev };
    allreduce(guard.w, comm, 8)
}

/// Ring allgather of `bytes` contributed per rank. Exhaustive tier
/// dispatch: `FabricTier::Des` executes all P-1 dependency-released
/// rounds streamed; the analytic tier prices one permutation round and
/// scales (every round is the same shift-by-one).
pub fn allgather(w: &mut World, comm: &Comm, bytes_per_rank: u64) -> f64 {
    let p = comm.size();
    if p <= 1 {
        return trivial_collective(w, comm);
    }
    match w.tier {
        FabricTier::Des => des_collective(w, comm, |k| {
            allgather_round_k(comm, bytes_per_rank, k)
        }),
        FabricTier::Analytic => {
            let msgs: Vec<_> = (0..p)
                .map(|i| {
                    (comm.ranks[i], comm.ranks[(i + 1) % p], bytes_per_rank)
                })
                .collect();
            let t = (p - 1) as f64 * round_cost(w, &msgs);
            w.sync_clocks(comm, t);
            t
        }
    }
}

/// Ring reduce-scatter over a `bytes` buffer. Exhaustive tier dispatch
/// (see [`allgather`]).
pub fn reduce_scatter(w: &mut World, comm: &Comm, bytes: u64) -> f64 {
    let p = comm.size();
    if p <= 1 {
        return trivial_collective(w, comm);
    }
    match w.tier {
        FabricTier::Des => des_collective(w, comm, |k| {
            reduce_scatter_round_k(comm, bytes, k)
        }),
        FabricTier::Analytic => {
            let chunk = (bytes / p as u64).max(1);
            let msgs: Vec<_> = (0..p)
                .map(|i| (comm.ranks[i], comm.ranks[(i + 1) % p], chunk))
                .collect();
            let t = (p - 1) as f64 * round_cost(w, &msgs);
            w.sync_clocks(comm, t);
            t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::machine::Machine;
    use crate::mpi::World;

    fn setup(nodes: usize, ppn: usize) -> (Machine, Vec<crate::node::RankLoc>) {
        let m = Machine::new(&AuroraConfig::small(8, 4)); // 64 nodes
        let p = m.place_job(0, nodes, ppn);
        (m, p)
    }

    #[test]
    fn allreduce_small_uses_tree_and_scales_logarithmically() {
        let (m, p) = setup(64, 1);
        let mut w = World::new(&m.topo, p);
        let comm16 = Comm::world(16);
        let comm64 = Comm::world(64);
        let t16 = allreduce_tree_time(&mut w, &comm16, 8);
        let t64 = allreduce_tree_time(&mut w, &comm64, 8);
        // log2(64)/log2(16) = 1.5; allow fabric noise
        assert!(t64 < t16 * 2.5, "tree must be sub-linear: {t16} {t64}");
        assert!(t64 > t16, "more ranks cannot be faster");
    }

    #[test]
    fn allreduce_switches_algorithm_at_cutoff() {
        let (m, p) = setup(16, 1);
        let cutoff = m.cfg.allreduce_tree_cutoff;
        let mut w = World::new(&m.topo, p);
        let comm = Comm::world(16);
        // at the cutoff boundary, ring (bytes/P chunks) beats tree for
        // large payloads — that's why MPICH switches
        let tree_big = allreduce_tree_time(&mut w, &comm, 64 * cutoff);
        let ring_big = allreduce_ring_time(&mut w, &comm, 64 * cutoff);
        assert!(ring_big < tree_big, "ring {ring_big} tree {tree_big}");
        let tree_small = allreduce_tree_time(&mut w, &comm, 8);
        let ring_small = allreduce_ring_time(&mut w, &comm, 8);
        assert!(tree_small < ring_small, "tree wins small messages");
    }

    #[test]
    fn allreduce_data_sums() {
        let (m, p) = setup(4, 2);
        let mut w = World::new(&m.topo, p);
        let comm = Comm::world(8);
        let mut bufs: Vec<Vec<f64>> =
            (0..8).map(|i| vec![i as f64, 1.0]).collect();
        let t = allreduce_data(&mut w, &comm, &mut bufs);
        assert!(t > 0.0);
        for b in &bufs {
            assert_eq!(b[0], 28.0); // 0+1+..+7
            assert_eq!(b[1], 8.0);
        }
    }

    #[test]
    fn allreduce_nonpow2_works() {
        let (m, p) = setup(12, 1);
        let mut w = World::new(&m.topo, p);
        let comm = Comm::world(12);
        let mut bufs: Vec<Vec<f64>> = (0..12).map(|_| vec![1.0; 4]).collect();
        allreduce_data(&mut w, &comm, &mut bufs);
        for b in &bufs {
            assert!(b.iter().all(|&v| v == 12.0));
        }
    }

    #[test]
    fn alltoall_data_transposes() {
        let (m, p) = setup(4, 1);
        let mut w = World::new(&m.topo, p);
        let comm = Comm::world(4);
        let bufs: Vec<Vec<Vec<f64>>> = (0..4)
            .map(|i| (0..4).map(|j| vec![(i * 10 + j) as f64]).collect())
            .collect();
        let (recv, t) = alltoall_data(&mut w, &comm, &bufs);
        assert!(t > 0.0);
        // rank j receives block i -> value i*10 + j
        for j in 0..4 {
            for i in 0..4 {
                assert_eq!(recv[j][i][0], (i * 10 + j) as f64);
            }
        }
    }

    #[test]
    fn bcast_scales_logarithmically() {
        let (m, p) = setup(32, 1);
        let mut w = World::new(&m.topo, p);
        let t8 = bcast(&mut w, &Comm::world(8), 0, 1 << 16);
        let mut w2 = World::new(&m.topo, m.place_job(0, 32, 1));
        let t32 = bcast(&mut w2, &Comm::world(32), 0, 1 << 16);
        assert!(t32 < t8 * 2.1, "binomial bcast is log-depth: {t8} {t32}");
    }

    #[test]
    fn barrier_is_fast() {
        let (m, p) = setup(16, 1);
        let mut w = World::new(&m.topo, p);
        let t = barrier(&mut w, &Comm::world(16));
        assert!(t < 100e-6, "barrier {t}");
    }

    #[test]
    fn allgather_linear_in_contributed_bytes() {
        let (m, p) = setup(16, 1);
        let mut w = World::new(&m.topo, p);
        let comm = Comm::world(16);
        let t_small = allgather(&mut w, &comm, 1 << 10);
        let mut w2 = World::new(&m.topo, m.place_job(0, 16, 1));
        let t_big = allgather(&mut w2, &Comm::world(16), 1 << 20);
        assert!(t_big > t_small * 10.0, "{t_small} vs {t_big}");
    }

    #[test]
    fn reduce_scatter_cheaper_than_full_allreduce_ring() {
        // reduce_scatter is the first half of the ring allreduce
        let (m, p) = setup(16, 1);
        let bytes = 16 << 20;
        let mut w = World::new(&m.topo, p);
        let rs = reduce_scatter(&mut w, &Comm::world(16), bytes);
        let mut w2 = World::new(&m.topo, m.place_job(0, 16, 1));
        let ar = allreduce_ring_time(&mut w2, &Comm::world(16), bytes);
        assert!(rs < ar, "rs {rs} allreduce {ar}");
        assert!(rs > ar * 0.3, "rs should be roughly half: {rs} vs {ar}");
    }

    #[test]
    fn single_rank_collectives_are_free() {
        let (m, p) = setup(2, 1);
        let mut w = World::new(&m.topo, p);
        let one = Comm { ranks: vec![0] };
        assert_eq!(allreduce(&mut w, &one, 1 << 20), 0.0);
        assert_eq!(alltoall(&mut w, &one, 1 << 20), 0.0);
        assert_eq!(bcast(&mut w, &one, 0, 1 << 20), 0.0);
        assert_eq!(allgather(&mut w, &one, 1 << 20), 0.0);
    }

    #[test]
    fn des_tier_allreduce_is_positive_and_syncs_clocks() {
        let (m, p) = setup(8, 1);
        let mut w = World::new(&m.topo, p).des_fabric();
        let comm = Comm::world(8);
        let t = allreduce(&mut w, &comm, 1 << 20);
        assert!(t > 0.0);
        let t0 = w.clock[0];
        assert!(w.clock.iter().all(|&c| (c - t0).abs() < 1e-12));
    }

    #[test]
    fn des_tier_tracks_analytic_within_a_band() {
        // closed-loop execution prices the same round structure, so on an
        // otherwise idle fabric the two tiers agree to within a small
        // factor (the DES sees per-round latency tails and max-min rates
        // instead of the analytic bottleneck-service approximation)
        let (m, p) = setup(8, 1);
        let mut wa = World::new(&m.topo, p);
        let ta = allreduce(&mut wa, &Comm::world(8), 8 << 20);
        let mut wd = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        let td = allreduce(&mut wd, &Comm::world(8), 8 << 20);
        let ratio = td / ta;
        assert!(
            (0.2..=5.0).contains(&ratio),
            "des {td} vs analytic {ta} (x{ratio:.2})"
        );
    }

    #[test]
    fn des_tier_alltoall_and_barrier_run() {
        let (m, p) = setup(6, 1);
        let mut w = World::new(&m.topo, p).des_fabric();
        let comm = Comm::world(6);
        let ta = alltoall(&mut w, &comm, 64 << 10);
        assert!(ta > 0.0);
        let tb = barrier(&mut w, &comm);
        assert!(tb > 0.0 && tb < ta, "barrier {tb} alltoall {ta}");
    }

    #[test]
    fn barrier_rides_low_latency_class() {
        // §3.1 bugfix regression: barrier flows must be recorded on the
        // LowLatency class (they were priced as BestEffort), and the
        // world's own class must be restored afterwards
        for des in [false, true] {
            let (m, p) = setup(16, 1);
            let mut w = World::new(&m.topo, p);
            if des {
                w = w.des_fabric();
            }
            let t = barrier(&mut w, &Comm::world(16));
            assert!(t > 0.0);
            let ll = w.counters.class_msgs(TrafficClass::LowLatency);
            let be = w.counters.class_msgs(TrafficClass::BestEffort);
            assert!(ll > 0, "barrier sent no LowLatency flows (des={des})");
            assert_eq!(
                be, 0,
                "barrier flows leaked onto BestEffort (des={des})"
            );
            assert_eq!(w.class, TrafficClass::BestEffort, "class restored");
        }
    }

    #[test]
    fn des_tier_bcast_allgather_reduce_scatter_run_closed_loop() {
        // full collective coverage: no silent analytic fallback on a
        // des_fabric() world — positive makespans, clocks synced
        let (m, p) = setup(12, 1);
        let mut w = World::new(&m.topo, p).des_fabric();
        let comm = Comm::world(12);
        let tb = bcast(&mut w, &comm, 0, 1 << 20);
        assert!(tb > 0.0, "bcast {tb}");
        let tg = allgather(&mut w, &comm, 1 << 20);
        assert!(tg > 0.0, "allgather {tg}");
        let tr = reduce_scatter(&mut w, &comm, 12 << 20);
        assert!(tr > 0.0, "reduce_scatter {tr}");
        let t0 = w.clock[0];
        assert!(t0 > 0.0);
        assert!(w.clock.iter().all(|&c| (c - t0).abs() < 1e-12));
        // allgather moves P-1 full contributions; reduce-scatter the
        // same round count in bytes/P chunks of an equal total buffer —
        // so allgather of the same per-rank payload must cost more
        let (m2, p2) = setup(12, 1);
        let mut w2 = World::new(&m2.topo, p2).des_fabric();
        let tg2 = allgather(&mut w2, &comm, 12 << 20);
        assert!(tg2 > tr, "allgather {tg2} vs reduce_scatter {tr}");
    }

    #[test]
    fn des_tier_tracks_analytic_for_new_collectives() {
        // on an idle fabric the closed-loop pricing of the newly covered
        // collectives stays within a small band of the analytic tier
        let (m, p) = setup(8, 1);
        let comm = Comm::world(8);
        let mut wa = World::new(&m.topo, p);
        let ta = allgather(&mut wa, &comm, 4 << 20);
        let mut wd = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        let td = allgather(&mut wd, &comm, 4 << 20);
        let ratio = td / ta;
        assert!(
            (0.2..=5.0).contains(&ratio),
            "allgather des {td} vs analytic {ta} (x{ratio:.2})"
        );
    }

    #[test]
    fn new_round_generators_shapes() {
        let comm = Comm::world(12);
        let bc = bcast_rounds(&comm, 0, 1 << 10);
        // reach 1, 2, 4, 8 -> 4 rounds of sizes 1, 2, 4, 4
        assert_eq!(bc.len(), 4);
        assert_eq!(
            bc.iter().map(Vec::len).collect::<Vec<_>>(),
            vec![1, 2, 4, 4]
        );
        let ag = allgather_rounds(&comm, 1 << 10);
        assert_eq!(ag.len(), 11);
        assert!(ag.iter().all(|r| r.len() == 12));
        assert!(ag[0].iter().all(|&(_, _, b)| b == 1 << 10));
        let rs = reduce_scatter_rounds(&comm, 12 << 10);
        assert_eq!(rs.len(), 11);
        assert!(rs[0].iter().all(|&(_, _, b)| b == 1 << 10));
        // lazy and materialized forms agree round by round
        for (k, r) in allreduce_ring_rounds(&comm, 1 << 20)
            .iter()
            .enumerate()
        {
            assert_eq!(
                Some(r.clone()),
                allreduce_ring_round_k(&comm, 1 << 20, k)
            );
        }
        assert_eq!(allreduce_ring_round_k(&comm, 1 << 20, 22), None);
    }

    #[test]
    fn streamed_collective_matches_materialized_rounds_dag() {
        // the one seam between the Des-tier arms: stream_rounds's
        // rank-keyed StreamNode construction (incl. the intra-node
        // Compute dispatch at ppn=2) against rounds_dag + run_dag on
        // identical worlds — 1e-9, not a band
        use crate::fabric::des::{DesOpts, DesSim};
        let (m, p) = setup(8, 2); // 16 ranks, 2 per node
        let comm = Comm::world(16);
        let rounds = allreduce_ring_rounds(&comm, 4 << 20);
        let mut w1 = World::new(&m.topo, p);
        let dag = rounds_dag(&mut w1, &rounds);
        let full = DesSim::new(&m.topo, DesOpts::default())
            .run_dag(&dag)
            .makespan;
        let mut w2 = World::new(&m.topo, m.place_job(0, 8, 2));
        let streamed = stream_rounds(&mut w2, |k| rounds.get(k).cloned());
        let rel = (full - streamed).abs() / full.max(1e-30);
        assert!(
            rel < 1e-9,
            "streamed {streamed} vs materialized {full} (rel {rel:.2e})"
        );
    }

    #[test]
    fn staged_collective_flushes_pending_exchanges() {
        // a collective inside a superstep prices the pending exchange
        // rounds and its own rounds as ONE dependency-released DAG
        let (m, p) = setup(8, 1);
        let mut w = World::new(&m.topo, p).des_fabric();
        w.begin_superstep();
        w.exchange(&[(0, 1, 4 << 20), (2, 3, 4 << 20)]);
        let t = allreduce(&mut w, &Comm::world(8), 8);
        assert!(t > 0.0);
        assert!(w.staging(), "staging stays active after the flush");
        let t0 = w.clock[0];
        assert!(t0 > 0.0);
        assert!(
            w.clock[..8].iter().all(|&c| (c - t0).abs() < 1e-12),
            "collective flush must sync the comm"
        );
        w.end_superstep();
    }

    #[test]
    fn allreduce_rounds_match_analytic_round_counts() {
        let comm = Comm::world(12); // non-power-of-two: fold rounds
        let tree = allreduce_tree_rounds(&comm, 1024);
        // fold-in + log2(8) + fold-out
        assert_eq!(tree.len(), 1 + 3 + 1);
        assert_eq!(tree[0].len(), 4); // 12 - 8 remainders
        assert_eq!(tree[1].len(), 8);
        let ring = allreduce_ring_rounds(&comm, 12 << 10);
        assert_eq!(ring.len(), 2 * 11);
        assert!(ring.iter().all(|r| r.len() == 12));
        assert!(ring[0].iter().all(|&(_, _, b)| b == 1 << 10));
        let a2a = alltoall_rounds(&comm, 256);
        assert_eq!(a2a.len(), 11);
    }

    #[test]
    fn rounds_dag_serializes_dependent_rounds() {
        let (m, p) = setup(8, 1);
        let mut w = World::new(&m.topo, p);
        let comm = Comm::world(8);
        let rounds = allreduce_ring_rounds(&comm, 8 << 20);
        let one = rounds_dag(&mut w, &rounds[..1]);
        let all = rounds_dag(&mut w, &rounds);
        let sim_one = crate::fabric::des::DesSim::new(
            &m.topo, crate::fabric::des::DesOpts::default());
        let t1 = sim_one.run_dag(&one).makespan;
        let tn = sim_one.run_dag(&all).makespan;
        // 14 dependency-chained rounds must take far longer than one
        assert!(tn > t1 * 6.0, "one {t1} vs all {tn}");
    }

    #[test]
    fn gpu_buffer_allreduce_slower_than_host() {
        // Fig 14 uses GPU buffers; the GPU path pays the PCIe conversion
        let (m, p) = setup(32, 1);
        let mut wh = World::new(&m.topo, p);
        let th = allreduce(&mut wh, &Comm::world(32), 16 << 20);
        let mut wg =
            World::new(&m.topo, m.place_job(0, 32, 1)).gpu_buffers();
        let tg = allreduce(&mut wg, &Comm::world(32), 16 << 20);
        assert!(tg > th, "gpu {tg} host {th}");
    }

    #[test]
    fn collectives_sync_all_clocks() {
        let (m, p) = setup(8, 1);
        let mut w = World::new(&m.topo, p);
        let comm = Comm::world(8);
        allreduce(&mut w, &comm, 1024);
        let t0 = w.clock[0];
        assert!(t0 > 0.0);
        assert!(w.clock.iter().all(|&c| (c - t0).abs() < 1e-12));
    }
}
