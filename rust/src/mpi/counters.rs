//! CXI counter collection (paper §3.8.8): HPE Cray MPI gathers Cassini
//! counters for any MPI job via MPICH_OFI_CXI_COUNTER_REPORT — no source
//! changes. We model the counters the fabric-validation flow reads:
//! per-NIC messages/bytes, retries and timeouts.

use crate::fabric::TrafficClass;
use std::collections::{BTreeMap, HashMap};

#[derive(Debug, Clone, Default)]
pub struct NicCounters {
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    pub retries: u64,
}

/// Aggregated CXI counters for a job.
#[derive(Debug, Clone, Default)]
pub struct CxiCounters {
    pub per_nic: HashMap<u32, NicCounters>,
    /// Messages per QoS traffic class (§4.2.3): lets tests and the
    /// fabric-validation flow confirm which class an operation rode —
    /// e.g. that barriers use LowLatency (§3.1).
    pub msgs_by_class: BTreeMap<TrafficClass, u64>,
    /// CXI-level timeouts (the §3.8.6 summary line).
    pub timeouts: u64,
}

impl CxiCounters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_send(&mut self, nic: u32, bytes: u64) {
        self.record_send_class(nic, bytes, TrafficClass::BestEffort);
    }

    /// Record a send on its QoS class (fabric flows carry `flow.class`).
    pub fn record_send_class(
        &mut self,
        nic: u32,
        bytes: u64,
        class: TrafficClass,
    ) {
        let c = self.per_nic.entry(nic).or_default();
        c.msgs_sent += 1;
        c.bytes_sent += bytes;
        *self.msgs_by_class.entry(class).or_default() += 1;
    }

    /// Messages recorded on `class`.
    pub fn class_msgs(&self, class: TrafficClass) -> u64 {
        self.msgs_by_class.get(&class).copied().unwrap_or(0)
    }

    pub fn record_retry(&mut self, nic: u32) {
        self.per_nic.entry(nic).or_default().retries += 1;
    }

    pub fn total_msgs(&self) -> u64 {
        self.per_nic.values().map(|c| c.msgs_sent).sum()
    }

    pub fn total_bytes(&self) -> u64 {
        self.per_nic.values().map(|c| c.bytes_sent).sum()
    }

    pub fn total_retries(&self) -> u64 {
        self.per_nic.values().map(|c| c.retries).sum()
    }

    /// The COUNTER_REPORT text (verbose form lists per-NIC rows).
    pub fn report(&self, verbose: bool) -> String {
        let mut s = format!(
            "CXI counter report: {} msgs, {} bytes, {} retries, {} timeouts\n",
            self.total_msgs(),
            self.total_bytes(),
            self.total_retries(),
            self.timeouts
        );
        if verbose {
            let mut nics: Vec<_> = self.per_nic.iter().collect();
            nics.sort_by_key(|(n, _)| **n);
            for (nic, c) in nics {
                s.push_str(&format!(
                    "  cxi{nic}: msgs={} bytes={} retries={}\n",
                    c.msgs_sent, c.bytes_sent, c.retries
                ));
            }
        }
        s
    }

    /// NICs whose send throughput is an outlier vs the median — the
    /// low-performing-node identification input of §3.8.7.
    pub fn low_outliers(&self, factor: f64) -> Vec<u32> {
        let mut bytes: Vec<u64> =
            self.per_nic.values().map(|c| c.bytes_sent).collect();
        if bytes.len() < 3 {
            return vec![];
        }
        bytes.sort_unstable();
        let median = bytes[bytes.len() / 2] as f64;
        self.per_nic
            .iter()
            .filter(|(_, c)| (c.bytes_sent as f64) < median * factor)
            .map(|(n, _)| *n)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals() {
        let mut c = CxiCounters::new();
        c.record_send(0, 100);
        c.record_send(0, 200);
        c.record_send(5, 50);
        c.record_retry(5);
        assert_eq!(c.total_msgs(), 3);
        assert_eq!(c.total_bytes(), 350);
        assert_eq!(c.total_retries(), 1);
    }

    #[test]
    fn verbose_report_lists_nics() {
        let mut c = CxiCounters::new();
        c.record_send(3, 10);
        let r = c.report(true);
        assert!(r.contains("cxi3: msgs=1 bytes=10"));
    }

    #[test]
    fn class_accounting() {
        let mut c = CxiCounters::new();
        c.record_send(0, 10); // defaults to BestEffort
        c.record_send_class(1, 10, TrafficClass::LowLatency);
        assert_eq!(c.class_msgs(TrafficClass::BestEffort), 1);
        assert_eq!(c.class_msgs(TrafficClass::LowLatency), 1);
        assert_eq!(c.class_msgs(TrafficClass::BulkData), 0);
        assert_eq!(c.total_msgs(), 2);
    }

    #[test]
    fn outlier_detection() {
        let mut c = CxiCounters::new();
        for nic in 0..8u32 {
            let b = if nic == 7 { 10 } else { 1000 };
            c.record_send(nic, b);
        }
        let low = c.low_outliers(0.5);
        assert_eq!(low, vec![7]);
    }
}
