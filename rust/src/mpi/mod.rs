//! The MPI runtime over the simulated fabric (paper §4.4-§4.5).
//!
//! Mirrors the Aurora software stack: MPICH CH4 -> libfabric CXI provider
//! -> Cassini NIC. A [`World`] holds rank placements, per-rank clocks and
//! the adaptive router; [`coll`] implements the collective algorithms
//! whose switch-over Fig 14 shows; [`rma`] implements the one-sided model
//! of §5.3.5 (software-emulated GPU RMA, HMEM path, fence-or-overflow);
//! [`counters`] is the CXI counter reporting of §3.8.8.
//!
//! Two usage modes share every code path:
//! * **timing**: ops advance per-rank clocks using the fabric cost tiers;
//! * **functional**: `*_data` variants also move/reduce real `f64`
//!   payloads so end-to-end numerics can be validated.

pub mod coll;
pub mod counters;
pub mod rma;

use crate::config::AuroraConfig;
use crate::fabric::des::{DesOpts, DesSim};
use crate::fabric::rounds::CostModel;
use crate::fabric::{BufLoc, Flow, Router, RoutedFlow, TrafficClass};
use crate::node::{NodePaths, RankLoc};
use crate::topology::Topology;
use counters::CxiCounters;

/// Which fabric tier prices collective rounds (see [`coll`]).
///
/// * `Analytic` (default): each round is costed independently by the
///   round/DES tier and rounds are summed — fast, but blind to
///   cross-round queueing dynamics.
/// * `Des`: collectives emit a dependency DAG of rounds executed
///   closed-loop on the DES ([`crate::fabric::DesSim::run_dag`]): a
///   round's completion releases the next round's flows, so congestion
///   and back-pressure propagate between rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricTier {
    Analytic,
    Des,
}

/// A communicator: an ordered set of world ranks.
#[derive(Debug, Clone)]
pub struct Comm {
    pub ranks: Vec<usize>,
}

impl Comm {
    pub fn world(n: usize) -> Self {
        Self { ranks: (0..n).collect() }
    }

    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// MPI_Comm_split by color; key = current order.
    pub fn split(&self, color: impl Fn(usize) -> usize) -> Vec<Comm> {
        let mut groups: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for (i, &r) in self.ranks.iter().enumerate() {
            groups.entry(color(i)).or_default().push(r);
        }
        groups.into_values().map(|ranks| Comm { ranks }).collect()
    }
}

/// The simulated MPI world.
pub struct World<'t> {
    pub topo: &'t Topology,
    pub router: Router<'t>,
    pub placements: Vec<RankLoc>,
    /// Global NIC id per rank.
    pub nics: Vec<u32>,
    /// Per-rank local clock (seconds).
    pub clock: Vec<f64>,
    pub counters: CxiCounters,
    /// Default buffer location for transfers (host or GPU-direct).
    pub buf: BufLoc,
    pub class: TrafficClass,
    /// Use the DES tier for rounds at or below this many flows; the
    /// round-based tier above (cross-validated in rust/tests).
    pub des_flow_limit: usize,
    /// How collectives are priced: analytic per-round (fast tier) or
    /// closed-loop dependency DAGs on the DES.
    pub tier: FabricTier,
    node_paths: NodePaths,
    des_opts: DesOpts,
}

impl<'t> World<'t> {
    pub fn new(topo: &'t Topology, placements: Vec<RankLoc>) -> Self {
        let nics = placements
            .iter()
            .map(|l| topo.nic_of_node(l.node, l.nic_idx))
            .collect();
        let n = placements.len();
        Self {
            topo,
            router: Router::new(topo),
            nics,
            clock: vec![0.0; n],
            counters: CxiCounters::new(),
            buf: BufLoc::Host,
            class: TrafficClass::BestEffort,
            des_flow_limit: 512,
            tier: FabricTier::Analytic,
            node_paths: NodePaths::new(&topo.cfg),
            des_opts: DesOpts::default(),
            placements,
        }
    }

    pub fn gpu_buffers(mut self) -> Self {
        self.buf = BufLoc::Gpu;
        self
    }

    /// Switch collectives onto the closed-loop DES tier.
    pub fn des_fabric(mut self) -> Self {
        self.tier = FabricTier::Des;
        self
    }

    pub fn size(&self) -> usize {
        self.placements.len()
    }

    pub fn cfg(&self) -> &AuroraConfig {
        &self.topo.cfg
    }

    pub fn cost_model(&self) -> CostModel<'t> {
        CostModel::new(self.topo)
    }

    /// Max clock across ranks — the job's elapsed time.
    pub fn elapsed(&self) -> f64 {
        self.clock.iter().cloned().fold(0.0, f64::max)
    }

    /// Advance every rank in `comm` to the same time (a synchronizing op).
    pub fn sync_clocks(&mut self, comm: &Comm, extra: f64) {
        let t = comm
            .ranks
            .iter()
            .map(|&r| self.clock[r])
            .fold(0.0, f64::max)
            + extra;
        for &r in &comm.ranks {
            self.clock[r] = t;
        }
    }

    /// Per-rank compute: advances that rank's clock only.
    pub fn compute(&mut self, rank: usize, seconds: f64) {
        self.clock[rank] += seconds;
    }

    /// Cost of one message between two ranks, ignoring cross-flow
    /// contention (used for tree collectives where rounds serialize).
    pub fn solo_msg_time(&mut self, src: usize, dst: usize, bytes: u64) -> f64 {
        let (a, b) = (self.placements[src], self.placements[dst]);
        if a.node == b.node {
            return self.intra_node_time(&a, &b, bytes);
        }
        let flow = self.flow(src, dst, bytes);
        let path = self.router.route(&flow);
        self.counters.record_send(self.nics[src], bytes);
        self.cost_model().solo_msg_time(&path, bytes, self.buf)
    }

    fn intra_node_time(&self, a: &RankLoc, b: &RankLoc, bytes: u64) -> f64 {
        let cfg = &self.topo.cfg;
        let bw = self
            .node_paths
            .intra_node_bw(a, b, matches!(self.buf, BufLoc::Gpu));
        // IPC-handle / shared-memory path: software overhead, no NIC
        0.4e-6 + cfg.mpi_overhead + bytes as f64 / bw
    }

    fn flow(&self, src: usize, dst: usize, bytes: u64) -> Flow {
        Flow {
            src_nic: self.nics[src],
            dst_nic: self.nics[dst],
            bytes,
            class: self.class,
            buf: self.buf,
            ordered: true, // MPI envelope ordering (§3.1)
        }
    }

    /// Execute one communication round: `(src, dst, bytes)` triples that
    /// start together. Advances the clocks of all participants; returns
    /// the round's duration (from the latest participant start).
    pub fn exchange(&mut self, msgs: &[(usize, usize, u64)]) -> f64 {
        if msgs.is_empty() {
            return 0.0;
        }
        // split intra-node messages (no fabric) from fabric flows
        let mut fabric_idx = Vec::new();
        let mut intra: Vec<(usize, f64)> = Vec::new();
        let mut routed = Vec::new();
        for (i, &(s, d, b)) in msgs.iter().enumerate() {
            let (pa, pb) = (self.placements[s], self.placements[d]);
            if pa.node == pb.node {
                intra.push((i, self.intra_node_time(&pa, &pb, b)));
            } else {
                let f = self.flow(s, d, b);
                let path = self.router.route(&f);
                self.counters.record_send(self.nics[s], b);
                routed.push(RoutedFlow { flow: f, path });
                fabric_idx.push(i);
            }
        }
        let start = msgs
            .iter()
            .flat_map(|&(s, d, _)| [self.clock[s], self.clock[d]])
            .fold(0.0, f64::max);
        let mut per_msg = vec![0.0f64; msgs.len()];
        for (i, t) in &intra {
            per_msg[*i] = *t;
        }
        if !routed.is_empty() {
            let times = if routed.len() <= self.des_flow_limit {
                DesSim::new(self.topo, self.des_opts.clone())
                    .run_simultaneous(&routed)
            } else {
                self.cost_model().eval_round(&routed)
            };
            for (k, &i) in fabric_idx.iter().enumerate() {
                per_msg[i] = times.per_flow[k];
            }
        }
        let mut round = 0.0f64;
        for (i, &(s, d, _)) in msgs.iter().enumerate() {
            let t = start + per_msg[i];
            self.clock[s] = self.clock[s].max(t);
            self.clock[d] = self.clock[d].max(t);
            round = round.max(per_msg[i]);
        }
        // ordered-delivery bookkeeping: destinations now idle
        for &(s, d, _) in msgs {
            self.router.destination_idle(self.nics[s], self.nics[d]);
        }
        round
    }

    /// Point-to-point latency with `window` outstanding messages (the
    /// ALCF benchmark of Fig 10 uses a 16-message window): reported value
    /// is the average per-message latency.
    pub fn p2p_latency(&mut self, src: usize, dst: usize, bytes: u64,
                       window: usize) -> f64 {
        let flow = self.flow(src, dst, bytes);
        let path = self.router.route(&flow);
        let cm = self.cost_model();
        let lat = cm.msg_latency(&path, bytes, self.buf);
        let ser = bytes as f64
            / cm.nic_eff_bw(self.buf).min(cm.rank_issue_bw(self.buf));
        // window messages pipeline over the wire: the first pays full
        // latency, the rest are serialization-gated
        let total =
            lat + window as f64 * ser.max(1.0 / self.topo.cfg.nic_msg_rate);
        self.counters.record_send(self.nics[src], bytes * window as u64);
        lat.max(total / window as f64)
    }

    /// Inject network timeouts (fabric events / node issues — §3.8.6).
    pub fn inject_timeouts(&mut self, n: u64) {
        self.counters.timeouts += n;
    }

    /// The MPICH summary line printed after a job (§3.8.6).
    pub fn mpich_summary(&self) -> String {
        format!(
            "MPICH Slingshot Network Summary: {} network timeouts.",
            self.counters.timeouts
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::machine::Machine;

    fn world(m: &Machine, nodes: usize, ppn: usize) -> World<'_> {
        World::new(&m.topo, m.place_job(0, nodes, ppn))
    }

    #[test]
    fn comm_split() {
        let c = Comm::world(12);
        let subs = c.split(|i| i / 4);
        assert_eq!(subs.len(), 3);
        assert_eq!(subs[0].ranks, vec![0, 1, 2, 3]);
    }

    #[test]
    fn exchange_advances_clocks() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 4, 2);
        let d = w.exchange(&[(0, 2, 4096), (4, 6, 4096)]);
        assert!(d > 0.0);
        assert!(w.clock[0] > 0.0 && w.clock[6] > 0.0);
        assert_eq!(w.clock[1], 0.0, "uninvolved rank unaffected");
    }

    #[test]
    fn intra_node_faster_than_inter_node() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 4, 2);
        let bytes = 1 << 20;
        let intra = w.solo_msg_time(0, 1, bytes); // same node, 2 ranks/node
        let inter = w.solo_msg_time(0, 7, bytes); // different nodes
        assert!(intra < inter, "intra {intra} inter {inter}");
    }

    #[test]
    fn p2p_latency_shape_matches_fig10() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 8, 1);
        let l8 = w.p2p_latency(0, 7, 8, 16);
        let l64 = w.p2p_latency(0, 7, 64, 16);
        let l128 = w.p2p_latency(0, 7, 128, 16);
        let l1m = w.p2p_latency(0, 7, 1 << 20, 16);
        assert!((l8 - l64).abs() < 0.15e-6, "flat small-msg region");
        assert!(l128 > l64, "SRAM->DRAM step");
        assert!(l1m > 20.0 * l128, "bandwidth regime");
    }

    #[test]
    fn counters_accumulate() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 2, 1);
        w.exchange(&[(0, 1, 1000)]);
        assert!(w.counters.total_bytes() >= 1000);
        w.inject_timeouts(28);
        assert_eq!(
            w.mpich_summary(),
            "MPICH Slingshot Network Summary: 28 network timeouts."
        );
    }

    #[test]
    fn sync_clocks_levels_ranks() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 2, 2);
        w.compute(0, 5.0);
        w.sync_clocks(&Comm::world(4), 0.0);
        assert!(w.clock.iter().all(|&c| c == 5.0));
    }
}
