//! The MPI runtime over the simulated fabric (paper §4.4-§4.5).
//!
//! Mirrors the Aurora software stack: MPICH CH4 -> libfabric CXI provider
//! -> Cassini NIC. A [`World`] holds rank placements, per-rank clocks and
//! the adaptive router; [`coll`] implements the collective algorithms
//! whose switch-over Fig 14 shows; [`rma`] implements the one-sided model
//! of §5.3.5 (software-emulated GPU RMA, HMEM path, fence-or-overflow);
//! [`counters`] is the CXI counter reporting of §3.8.8.
//!
//! Two usage modes share every code path:
//! * **timing**: ops advance per-rank clocks using the fabric cost tiers;
//! * **functional**: `*_data` variants also move/reduce real `f64`
//!   payloads so end-to-end numerics can be validated.

pub mod coll;
pub mod counters;
pub mod rma;

use crate::config::AuroraConfig;
use crate::fabric::arrivals::{
    run_open_loop, PoissonArrivals, RpcClass, SteadyState,
};
use crate::fabric::des::{DesOpts, DesScratch, DesSim};
use crate::fabric::rounds::CostModel;
use crate::fabric::workload::{DagBuilder, StreamNode};
use crate::fabric::{BufLoc, Flow, Router, RoutedFlow, TrafficClass};
use crate::node::{NodePaths, RankLoc};
use crate::topology::{LinkId, Topology};
use counters::CxiCounters;
use rustc_hash::{FxHashMap, FxHashSet};

/// Which fabric tier prices collective rounds (see [`coll`]).
///
/// * `Analytic` (default): each round is costed independently by the
///   round/DES tier and rounds are summed — fast, but blind to
///   cross-round queueing dynamics.
/// * `Des`: collectives emit a dependency DAG of rounds executed
///   closed-loop on the DES ([`crate::fabric::DesSim::run_dag`]): a
///   round's completion releases the next round's flows, so congestion
///   and back-pressure propagate between rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricTier {
    Analytic,
    Des,
}

/// A communicator: an ordered set of world ranks.
#[derive(Debug, Clone)]
pub struct Comm {
    pub ranks: Vec<usize>,
}

impl Comm {
    pub fn world(n: usize) -> Self {
        Self { ranks: (0..n).collect() }
    }

    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// MPI_Comm_split by color; key = current order.
    pub fn split(&self, color: impl Fn(usize) -> usize) -> Vec<Comm> {
        let mut groups: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for (i, &r) in self.ranks.iter().enumerate() {
            groups.entry(color(i)).or_default().push(r);
        }
        groups.into_values().map(|ranks| Comm { ranks }).collect()
    }
}

/// One staged superstep node (`FabricTier::Des`). Fabric transfers
/// capture everything routing needs at *stage* time (NICs are derivable
/// from the rank, class/buffer/ordering are snapshotted) but are routed
/// lazily at the flush — in staging order, so pinned-route replay and
/// adaptive decisions see the same sequence the eager path saw — which
/// keeps the staged representation at a few dozen bytes per message
/// instead of a routed path plus a DAG node.
#[derive(Debug, Clone, Copy)]
enum StagedNode {
    /// [`World::superstep_compute`]: serialized on `rank`'s chain.
    Compute { rank: usize, dt: f64, floor: f64 },
    /// Intra-node message: fixed duration, no fabric.
    Intra { s: usize, d: usize, dt: f64, floor: f64 },
    /// Fabric transfer, routed at flush time.
    Xfer {
        s: usize,
        d: usize,
        bytes: u64,
        class: TrafficClass,
        buf: BufLoc,
        ordered: bool,
        floor: f64,
    },
}

impl StagedNode {
    /// World-rank participants (clock-advance targets).
    fn participants(&self) -> (usize, usize) {
        match *self {
            StagedNode::Compute { rank, .. } => (rank, rank),
            StagedNode::Intra { s, d, .. }
            | StagedNode::Xfer { s, d, .. } => (s, d),
        }
    }

    /// Source key for round-release semantics (a round-k node is
    /// released by the round-(k-1) nodes touching its source).
    fn source(&self) -> usize {
        match *self {
            StagedNode::Compute { rank, .. } => rank,
            StagedNode::Intra { s, .. } | StagedNode::Xfer { s, .. } => s,
        }
    }

    fn floor(&self) -> f64 {
        match *self {
            StagedNode::Compute { floor, .. }
            | StagedNode::Intra { floor, .. }
            | StagedNode::Xfer { floor, .. } => floor,
        }
    }

    /// The fabric flow of an `Xfer` node (`None` otherwise) — the ONE
    /// place both flush arms (streamed and materialized) build the Flow
    /// from, so their routing inputs cannot diverge.
    fn fabric_flow(&self, nics: &[u32]) -> Option<Flow> {
        match *self {
            StagedNode::Xfer { s, d, bytes, class, buf, ordered, .. } => {
                Some(Flow {
                    src_nic: nics[s],
                    dst_nic: nics[d],
                    bytes,
                    class,
                    buf,
                    ordered,
                })
            }
            _ => None,
        }
    }
}

/// Superstep staging state (`FabricTier::Des`): exchanges accumulate as
/// dependency-released rounds keyed by world rank and are priced as one
/// closed-loop run at the next flush point (a collective, or an explicit
/// [`World::flush_steps`] / [`World::end_superstep`]). Rounds are held
/// as unrouted triples; [`World::execute_staged`] feeds them through the
/// **streamed** executor (`DesSim::run_stream`) with per-rank clock
/// floors whenever the static analysis proves exactness, falling back
/// to the fully materialized `run_dag` otherwise.
#[derive(Default)]
struct StagedSteps {
    /// Round-structured staged nodes. A run of consecutive
    /// [`World::superstep_compute`] calls shares one round (per-rank
    /// chains are independent), re-splitting if one rank stages two
    /// computes in a row.
    rounds: Vec<Vec<StagedNode>>,
    n_nodes: usize,
    /// Whether the last round is an open compute batch, and which ranks
    /// it already holds.
    open_compute: bool,
    batch_ranks: FxHashSet<usize>,
}

/// Diagnostics of the most recent superstep flush (Des tier) — see
/// [`World::last_flush`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlushStats {
    /// Whether the flush ran on the windowed streaming executor (true)
    /// or fell back to the fully materialized `run_dag` (false: the
    /// staged structure admitted a potentially-late release).
    pub streamed: bool,
    /// Non-empty staged rounds priced.
    pub rounds: usize,
    /// Total nodes priced.
    pub total_nodes: usize,
    /// Peak simultaneously live nodes (`== total_nodes` when
    /// materialized; bounded by the dependency-skew window when
    /// streamed).
    pub peak_live_nodes: usize,
    /// Releases clamped by late materialization — 0 on both paths (the
    /// streamed path is only taken when the analysis proves exactness).
    pub late_releases: usize,
}

/// The intra-node (IPC-handle / shared-memory) message time: software
/// overhead + path bandwidth, no NIC. The ONE definition of this model —
/// `World::intra_node_time`, `coll::round_cost` and the Des-tier stream
/// source all call it (the latter two cannot take `&World` because they
/// hold disjoint field borrows), so the intra-node pricing cannot
/// silently diverge between tiers.
pub(crate) fn intra_node_time(
    node_paths: &NodePaths,
    cfg: &AuroraConfig,
    a: &RankLoc,
    b: &RankLoc,
    gpu_buf: bool,
    bytes: u64,
) -> f64 {
    0.4e-6
        + cfg.mpi_overhead
        + bytes as f64 / node_paths.intra_node_bw(a, b, gpu_buf)
}

/// Static exactness analysis for the streamed superstep flush: the
/// windowed executor reproduces `run_dag` exactly iff no node is
/// materialized after all of its dependencies have already finished.
/// Rounds materialized at *bootstrap* (before the clock starts) are
/// always exact: round 0 plus the cascade reachable through
/// dependency-free nodes (`reach` — a dependency-free node in round
/// k < reach extends materialization to k + 2). Past the bootstrap
/// prefix a round materializes when the previous round first releases,
/// so a node is exact iff its source key was touched in the immediately
/// preceding round. Anything else — a dependency-free node beyond the
/// bootstrap prefix, or a source key last touched two or more rounds
/// back — could release late, and the flush falls back to the
/// materialized path (identical semantics, full memory).
fn staged_flush_is_exact(rounds: &[Vec<StagedNode>]) -> bool {
    let r = rounds.len();
    if r <= 2 {
        return true; // rounds 0 and 1 always materialize at bootstrap
    }
    let mut last_touch: FxHashMap<usize, usize> = FxHashMap::default();
    for n in &rounds[0] {
        let (a, b) = n.participants();
        last_touch.insert(a, 0);
        last_touch.insert(b, 0);
    }
    let mut reach = 2usize;
    for (k, round) in rounds.iter().enumerate().skip(1) {
        for n in round {
            match last_touch.get(&n.source()) {
                None => {
                    // dependency-free: released at its floor — exact
                    // only when materialized at bootstrap, which also
                    // extends the bootstrap cascade
                    if k >= reach {
                        return false;
                    }
                    reach = reach.max(k + 2);
                }
                Some(&t) if t + 1 == k => {}
                Some(_) => {
                    // stale source: its dependencies may finish before
                    // round k materializes — exact only at bootstrap
                    if k >= reach {
                        return false;
                    }
                }
            }
        }
        for n in round {
            let (a, b) = n.participants();
            last_touch.insert(a, k);
            last_touch.insert(b, k);
        }
    }
    true
}

/// The simulated MPI world.
pub struct World<'t> {
    pub topo: &'t Topology,
    pub router: Router<'t>,
    pub placements: Vec<RankLoc>,
    /// Global NIC id per rank.
    pub nics: Vec<u32>,
    /// Per-rank local clock (seconds).
    pub clock: Vec<f64>,
    pub counters: CxiCounters,
    /// Default buffer location for transfers (host or GPU-direct).
    pub buf: BufLoc,
    pub class: TrafficClass,
    /// Use the DES tier for rounds at or below this many flows; the
    /// round-based tier above (cross-validated in rust/tests).
    pub des_flow_limit: usize,
    /// How collectives are priced: analytic per-round (fast tier) or
    /// closed-loop dependency DAGs on the DES.
    pub tier: FabricTier,
    node_paths: NodePaths,
    des_opts: DesOpts,
    /// `Some` while exchange supersteps are being staged (Des tier).
    staged: Option<StagedSteps>,
    /// Reusable DES solver arena: every staged flush / Des-tier
    /// exchange / analytic sub-limit round borrows this instead of
    /// reallocating (see [`DesScratch`]).
    scratch: DesScratch,
    /// Streamed superstep flush enabled (default). `false` forces every
    /// flush onto the fully materialized `run_dag` path — the
    /// equivalence reference the streamed flush is tested against.
    stream_flush: bool,
    /// Diagnostics of the most recent superstep flush (Des tier):
    /// recorded by flush points only (`end_superstep`, `flush_steps`,
    /// collective flushes) — an unstaged one-round `exchange` /
    /// `exchange_now` never overwrites it.
    pub last_flush: Option<FlushStats>,
}

impl<'t> World<'t> {
    pub fn new(topo: &'t Topology, placements: Vec<RankLoc>) -> Self {
        let nics = placements
            .iter()
            .map(|l| topo.nic_of_node(l.node, l.nic_idx))
            .collect();
        let n = placements.len();
        Self {
            topo,
            router: Router::new(topo),
            nics,
            clock: vec![0.0; n],
            counters: CxiCounters::new(),
            buf: BufLoc::Host,
            class: TrafficClass::BestEffort,
            des_flow_limit: 512,
            tier: FabricTier::Analytic,
            node_paths: NodePaths::new(&topo.cfg),
            des_opts: DesOpts::default(),
            staged: None,
            scratch: DesScratch::new(),
            stream_flush: true,
            last_flush: None,
            placements,
        }
    }

    pub fn gpu_buffers(mut self) -> Self {
        self.buf = BufLoc::Gpu;
        self
    }

    /// Switch collectives onto the closed-loop DES tier. Also enables
    /// the router's route cache: Des-tier collective rings and app halo
    /// loops re-send the same (src, dst) pair for O(P) rounds, so the
    /// adaptive decision is made once per pair and replayed (load still
    /// committed per flow; ordered exchange traffic keeps its pinned-
    /// route/idle semantics untouched — EXPERIMENTS.md §Route cache).
    pub fn des_fabric(mut self) -> Self {
        self.tier = FabricTier::Des;
        self.router.enable_route_cache();
        self
    }

    /// Toggle the streamed superstep flush (on by default); `false`
    /// forces the fully materialized `run_dag` flush — the reference
    /// `tests/des_equivalence.rs` compares the streamed flush against.
    pub fn superstep_streaming(&mut self, on: bool) {
        self.stream_flush = on;
    }

    /// Install §3.4 degraded-link bandwidth multipliers on BOTH pricing
    /// layers: the DES (which scales link capacities) and the router
    /// (whose congestion scores divide by *effective* bandwidth, so
    /// adaptive decisions divert off degraded links). Installing also
    /// invalidates cached and pinned routes decided against the old
    /// bandwidths (see [`Router::set_degraded`]).
    pub fn set_degraded(
        &mut self,
        degraded: std::collections::BTreeMap<LinkId, f64>,
    ) {
        self.router
            .set_degraded(degraded.iter().map(|(l, m)| (*l, *m)));
        self.des_opts.degraded = degraded;
    }

    /// Install a deterministic mid-run fault timeline
    /// ([`crate::fabric::faults::FaultSchedule`]) on this world's DES
    /// options: every subsequent Des-tier exchange, superstep flush and
    /// [`World::open_loop_service`] prices the schedule's events inside
    /// its event heap. Cached and pinned routes whose path crosses a
    /// link the timeline touches are invalidated (scoped, see
    /// [`Router::invalidate_links`]) — a decision made against the
    /// healthy fabric must not replay across a planned outage. Pass
    /// `None` to clear.
    pub fn inject_faults(
        &mut self,
        faults: Option<crate::fabric::faults::FaultSchedule>,
    ) {
        if let Some(fs) = &faults {
            self.router.invalidate_links(
                fs.touched_links(self.topo.cfg.nics_per_node),
            );
        }
        self.des_opts.faults = faults;
    }

    /// Install a graceful-degradation [`crate::fabric::ServicePolicy`]
    /// (admission shedding, deadlines, retry budgets, hedging) on this
    /// world's DES options: every subsequent
    /// [`World::open_loop_service`] run enforces it (per-class
    /// shed/abandoned/failed/hedged counters and goodput come back in
    /// the [`SteadyState`]). Admission, deadlines and hedging only arm
    /// on the streaming executor; the class-0 retry budget also bounds
    /// retry-backoff re-arms in batch Des-tier exchanges. Pass `None`
    /// to clear; an inert policy is bit-identical to none.
    pub fn set_service_policy(
        &mut self,
        policy: Option<crate::fabric::ServicePolicy>,
    ) {
        self.des_opts.policies = policy;
    }

    /// Run an open-loop Poisson RPC service over this world's rank NICs
    /// on the bounded-memory streaming tier ([`crate::fabric::arrivals`]):
    /// `arrivals` flows at `rate`/s, sizes drawn from `mix`, batched
    /// into `quantum`-second materialization windows with steady-state
    /// metrics collected per `window` seconds. Uses the world's router
    /// (so degraded links installed via [`World::set_degraded`] shape
    /// the service traffic) and its reusable DES scratch. Arrival times
    /// are absolute (the service occupies `[0, makespan]`); every rank
    /// clock is advanced to at least the service makespan. Service
    /// flows bypass the per-rank CXI send counters — they model
    /// background RPC load, not MPI traffic.
    pub fn open_loop_service(
        &mut self,
        seed: u64,
        rate: f64,
        arrivals: u64,
        mix: Vec<RpcClass>,
        quantum: f64,
        window: f64,
    ) -> SteadyState {
        let sim = DesSim::new(self.topo, self.des_opts.clone());
        let src = PoissonArrivals::new(
            seed,
            rate,
            arrivals,
            self.nics.clone(),
            mix,
        );
        let (res, ss) = {
            let World { router, scratch, .. } = &mut *self;
            run_open_loop(&sim, scratch, src, router, quantum, window)
        };
        debug_assert_eq!(
            res.late_releases, 0,
            "open-loop arrivals are floor-released, never late"
        );
        for c in &mut self.clock {
            if *c < res.makespan {
                *c = res.makespan;
            }
        }
        ss
    }

    pub fn size(&self) -> usize {
        self.placements.len()
    }

    pub fn cfg(&self) -> &AuroraConfig {
        &self.topo.cfg
    }

    pub fn cost_model(&self) -> CostModel<'t> {
        CostModel::new(self.topo)
    }

    /// Max clock across ranks — the job's elapsed time.
    pub fn elapsed(&self) -> f64 {
        self.clock.iter().cloned().fold(0.0, f64::max)
    }

    /// Advance every rank in `comm` to the same time (a synchronizing op).
    pub fn sync_clocks(&mut self, comm: &Comm, extra: f64) {
        let t = comm
            .ranks
            .iter()
            .map(|&r| self.clock[r])
            .fold(0.0, f64::max)
            + extra;
        for &r in &comm.ranks {
            self.clock[r] = t;
        }
    }

    /// Per-rank compute: advances that rank's clock only.
    pub fn compute(&mut self, rank: usize, seconds: f64) {
        self.clock[rank] += seconds;
    }

    /// Cost of one message between two ranks, ignoring cross-flow
    /// contention (used for tree collectives where rounds serialize).
    pub fn solo_msg_time(&mut self, src: usize, dst: usize, bytes: u64) -> f64 {
        let (a, b) = (self.placements[src], self.placements[dst]);
        if a.node == b.node {
            return self.intra_node_time(&a, &b, bytes);
        }
        let flow = self.flow(src, dst, bytes);
        let path = self.router.route(&flow);
        self.counters.record_send_class(self.nics[src], bytes, flow.class);
        self.cost_model().solo_msg_time(&path, bytes, self.buf)
    }

    fn intra_node_time(&self, a: &RankLoc, b: &RankLoc, bytes: u64) -> f64 {
        intra_node_time(
            &self.node_paths,
            &self.topo.cfg,
            a,
            b,
            matches!(self.buf, BufLoc::Gpu),
            bytes,
        )
    }

    fn flow(&self, src: usize, dst: usize, bytes: u64) -> Flow {
        Flow {
            src_nic: self.nics[src],
            dst_nic: self.nics[dst],
            bytes,
            class: self.class,
            buf: self.buf,
            ordered: true, // MPI envelope ordering (§3.1)
        }
    }

    /// Whether exchange supersteps are currently being staged.
    pub fn staging(&self) -> bool {
        self.staged.is_some()
    }

    /// Begin dependency-released superstep staging (Des tier only; a
    /// no-op on the analytic tier). Subsequent [`World::exchange`]
    /// rounds accumulate into one closed-loop DAG — round k+1 released
    /// per rank by round k — instead of being priced independently.
    /// Collectives are flush points (their rounds join the staged DAG
    /// and the whole superstep prices as one dependency-released run);
    /// [`World::flush_steps`] flushes explicitly and
    /// [`World::end_superstep`] flushes and stops staging.
    pub fn begin_superstep(&mut self) {
        if matches!(self.tier, FabricTier::Des) && self.staged.is_none() {
            self.staged = Some(StagedSteps::default());
        }
    }

    /// Flush staged supersteps: price the accumulated DAG closed-loop,
    /// advance participant clocks to their node finishes, and keep
    /// staging active. Returns the flushed span (0 if nothing staged).
    pub fn flush_steps(&mut self) -> f64 {
        if self.staged.is_none() {
            return 0.0;
        }
        let t = self.end_superstep();
        self.staged = Some(StagedSteps::default());
        t
    }

    /// Flush staged supersteps and stop staging. Returns the wall span
    /// of the staged work (earliest release floor to last finish).
    pub fn end_superstep(&mut self) -> f64 {
        match self.staged.take() {
            Some(st) => {
                let (mk, min_floor, _, stats) = self.execute_staged(st);
                if stats.is_some() {
                    self.last_flush = stats;
                }
                mk - min_floor
            }
            None => 0.0,
        }
    }

    /// Per-rank compute inside a superstep: stages a compute node
    /// serialized after everything the rank has staged so far and gating
    /// the rank's next staged message — so compute genuinely separates
    /// staged communication phases in the priced DAG. Without an active
    /// superstep it is plain [`World::compute`] (immediate clock
    /// advance). Plain `compute` during staging only moves the wall
    /// clock (a release *floor*), which staged rounds already past that
    /// floor would overlap.
    pub fn superstep_compute(&mut self, rank: usize, seconds: f64) {
        if let Some(st) = &mut self.staged {
            let node = StagedNode::Compute {
                rank,
                dt: seconds.max(0.0),
                floor: self.clock[rank],
            };
            // consecutive computes batch into one round (per-rank chains
            // are independent); a second compute for the same rank must
            // serialize after the first, so it opens a new round
            if st.open_compute && st.batch_ranks.insert(rank) {
                st.rounds.last_mut().expect("open batch").push(node);
            } else {
                st.rounds.push(vec![node]);
                st.open_compute = true;
                st.batch_ranks.clear();
                st.batch_ranks.insert(rank);
            }
            st.n_nodes += 1;
        } else {
            self.compute(rank, seconds);
        }
    }

    /// Stage one round of triples into `st`: intra-node messages become
    /// fixed-duration nodes, fabric messages snapshot their routing
    /// inputs (class/buffer/ordering) and are routed lazily at the
    /// flush; every node gets a release floor at its participants'
    /// current clocks (a rank cannot take part before its local time).
    /// `ordered` selects the flow's delivery mode: exchange rounds keep
    /// MPI envelope ordering (`true`, pinned routes — the pre-existing
    /// `exchange` semantics), while collective rounds staged at a flush
    /// point use `false` so they route exactly like the streamed /
    /// `rounds_dag` Des paths. Counters are recorded at stage time
    /// (matching the eager-routing staged path of old).
    fn stage_round_inner(
        &mut self,
        st: &mut StagedSteps,
        msgs: &[(usize, usize, u64)],
        ordered: bool,
    ) {
        if msgs.is_empty() {
            return; // the executor skips empty rounds; stage none
        }
        st.open_compute = false;
        st.batch_ranks.clear();
        let mut round = Vec::with_capacity(msgs.len());
        for &(s, d, b) in msgs {
            let (pa, pb) = (self.placements[s], self.placements[d]);
            let floor = self.clock[s].max(self.clock[d]);
            if pa.node == pb.node {
                let dt = self.intra_node_time(&pa, &pb, b);
                round.push(StagedNode::Intra { s, d, dt, floor });
            } else {
                self.counters.record_send_class(self.nics[s], b, self.class);
                round.push(StagedNode::Xfer {
                    s,
                    d,
                    bytes: b,
                    class: self.class,
                    buf: self.buf,
                    ordered,
                    floor,
                });
            }
        }
        st.n_nodes += round.len();
        st.rounds.push(round);
    }

    /// Execute a staged superstep closed-loop and advance clocks.
    /// Whenever [`staged_flush_is_exact`] proves the window-driven
    /// release order exact (every app exchange-loop shape: halo /
    /// pairwise / ring rounds re-touching their ranks each round), the
    /// staged rounds are routed lazily and **streamed** through the
    /// session API's sink mode ([`DesSim::session`]) with per-rank clock
    /// floors, so peak
    /// memory is the dependency-skew window, not O(rounds x P) routed
    /// nodes; otherwise (sparse key gaps, e.g. a tree allreduce's
    /// remainder-fold flushed mid-superstep) it falls back to the fully
    /// materialized `run_dag` — identical results either way, asserted
    /// at 1e-9 by `tests/des_equivalence.rs`. Returns `(makespan,
    /// min_floor, max_floor)` — absolute last finish plus the earliest
    /// and latest release floors, so callers can report either the wall
    /// span of the whole superstep (`makespan - min_floor`) or, for a
    /// single round, the duration from the latest participant start
    /// (`makespan - max_floor`, the analytic-tier contract).
    fn execute_staged(
        &mut self,
        st: StagedSteps,
    ) -> (f64, f64, f64, Option<FlushStats>) {
        if st.n_nodes == 0 {
            return (0.0, 0.0, 0.0, None);
        }
        let (mut min_floor, mut max_floor) = (f64::INFINITY, 0.0f64);
        let mut meta: Vec<(usize, usize)> = Vec::with_capacity(st.n_nodes);
        for round in &st.rounds {
            for n in round {
                let f = n.floor();
                min_floor = min_floor.min(f);
                max_floor = max_floor.max(f);
                meta.push(n.participants());
            }
        }
        let sim = DesSim::new(self.topo, self.des_opts.clone());
        let streamed = self.stream_flush && staged_flush_is_exact(&st.rounds);
        let (mk, stats) = if streamed {
            let rounds = &st.rounds;
            let World { router, clock, scratch, nics, .. } = self;
            let mut k = 0usize;
            let mut src = || -> Option<Vec<StreamNode>> {
                let round = rounds.get(k)?;
                k += 1;
                Some(
                    round
                        .iter()
                        .map(|n| match *n {
                            StagedNode::Compute { rank, dt, floor } => {
                                StreamNode::Compute {
                                    a: rank as u32,
                                    b: rank as u32,
                                    dt,
                                    start: floor,
                                }
                            }
                            StagedNode::Intra { s, d, dt, floor } => {
                                StreamNode::Compute {
                                    a: s as u32,
                                    b: d as u32,
                                    dt,
                                    start: floor,
                                }
                            }
                            StagedNode::Xfer { s, d, floor, .. } => {
                                let f = n
                                    .fabric_flow(nics)
                                    .expect("Xfer carries a flow");
                                let path = router.route(&f);
                                StreamNode::Xfer {
                                    a: s as u32,
                                    b: d as u32,
                                    rf: RoutedFlow { flow: f, path },
                                    start: floor,
                                }
                            }
                        })
                        .collect(),
                )
            };
            let sink = |id: u32, t: f64| {
                let (a, b) = meta[id as usize];
                if clock[a] < t {
                    clock[a] = t;
                }
                if clock[b] < t {
                    clock[b] = t;
                }
            };
            let res = sim.session(scratch).stream_sink(&mut src, sink);
            debug_assert_eq!(
                res.late_releases, 0,
                "staged-flush exactness analysis admitted a late release"
            );
            let stats = FlushStats {
                streamed: true,
                rounds: res.rounds,
                total_nodes: res.total_nodes,
                peak_live_nodes: res.peak_live_nodes,
                late_releases: res.late_releases,
            };
            (res.makespan, stats)
        } else {
            let mut b = DagBuilder::new();
            for round in &st.rounds {
                for n in round {
                    match *n {
                        StagedNode::Compute { rank, dt, floor } => {
                            let id = b.compute(rank as u32, dt);
                            b.set_floor(id, floor);
                        }
                        StagedNode::Intra { s, d, dt, floor } => {
                            let id =
                                b.compute_staged(s as u32, d as u32, dt);
                            b.set_floor(id, floor);
                        }
                        StagedNode::Xfer { s, d, floor, .. } => {
                            let f = n
                                .fabric_flow(&self.nics)
                                .expect("Xfer carries a flow");
                            let path = self.router.route(&f);
                            let id = b.xfer(
                                s as u32,
                                d as u32,
                                RoutedFlow { flow: f, path },
                            );
                            b.set_floor(id, floor);
                        }
                    }
                }
                b.end_round();
            }
            let dag = b.finish();
            let res = sim.session(&mut self.scratch).dag(&dag);
            for (i, &t) in res.node_finish.iter().enumerate() {
                let (a, b) = meta[i];
                self.clock[a] = self.clock[a].max(t);
                self.clock[b] = self.clock[b].max(t);
            }
            let stats = FlushStats {
                streamed: false,
                rounds: st.rounds.len(),
                total_nodes: dag.len(),
                peak_live_nodes: dag.len(),
                late_releases: 0,
            };
            (res.makespan, stats)
        };
        // destination-idle bookkeeping clears pinned routes, so it only
        // applies to ordered (route-pinned) exchange flows — unordered
        // collective rounds never pin and must not unpin unrelated
        // ordered traffic
        for round in &st.rounds {
            for n in round {
                if let StagedNode::Xfer { s, d, ordered: true, .. } = *n {
                    self.router.destination_idle(self.nics[s], self.nics[d]);
                }
            }
        }
        (mk, min_floor.min(mk), max_floor, Some(stats))
    }

    /// Stage round triples after any pending exchanges and flush: the
    /// whole superstep — pending exchange rounds plus these rounds —
    /// prices as one dependency-released DAG (collective flush points).
    /// Staging stays active for the next superstep. Requires staging.
    pub(crate) fn stage_rounds_and_flush(
        &mut self,
        rounds: &[Vec<(usize, usize, u64)>],
    ) -> f64 {
        let mut st = self.staged.take().expect("superstep staging active");
        for round in rounds {
            self.stage_round_inner(&mut st, round, false);
        }
        let (mk, min_floor, _, stats) = self.execute_staged(st);
        if stats.is_some() {
            self.last_flush = stats;
        }
        self.staged = Some(StagedSteps::default());
        mk - min_floor
    }

    /// Execute one communication round: `(src, dst, bytes)` triples that
    /// start together. Advances the clocks of all participants; returns
    /// the round's duration (from the latest participant start).
    ///
    /// On `FabricTier::Des` the round runs **closed-loop**: while
    /// superstep staging is active ([`World::begin_superstep`]) it is
    /// staged — released per rank by the previous round, priced at the
    /// next flush point, return value 0.0 until then — and otherwise it
    /// executes immediately as a one-round dependency DAG with per-rank
    /// clock floors. The analytic tier keeps the original independent
    /// round pricing.
    pub fn exchange(&mut self, msgs: &[(usize, usize, u64)]) -> f64 {
        if msgs.is_empty() {
            return 0.0;
        }
        if matches!(self.tier, FabricTier::Des) {
            if let Some(mut st) = self.staged.take() {
                self.stage_round_inner(&mut st, msgs, true);
                self.staged = Some(st);
                return 0.0; // priced at the next flush point
            }
        }
        self.exchange_now(msgs)
    }

    /// Execute one round and price it **immediately**, regardless of
    /// superstep staging — for callers that consume the returned
    /// duration (the RMA wire round, the OSU bandwidth probes, anything
    /// dividing bytes by the result). Pending staged rounds are left
    /// pending and unpriced; call [`World::flush_steps`] first if this
    /// round must observe their clock effects.
    pub fn exchange_now(&mut self, msgs: &[(usize, usize, u64)]) -> f64 {
        if msgs.is_empty() {
            return 0.0;
        }
        match self.tier {
            FabricTier::Des => {
                let mut st = StagedSteps::default();
                self.stage_round_inner(&mut st, msgs, true);
                // single round: duration from the latest participant
                // start (max floor), matching the analytic contract —
                // pre-existing clock skew is not part of the round time.
                // The one-round stats are dropped: `last_flush` reports
                // superstep flushes only.
                let (mk, _, max_floor, _) = self.execute_staged(st);
                (mk - max_floor).max(0.0)
            }
            FabricTier::Analytic => self.exchange_analytic(msgs),
        }
    }

    /// The analytic-tier round pricing (independent per-round DES or
    /// round-tier evaluation above `des_flow_limit`).
    fn exchange_analytic(&mut self, msgs: &[(usize, usize, u64)]) -> f64 {
        // split intra-node messages (no fabric) from fabric flows
        let mut fabric_idx = Vec::new();
        let mut intra: Vec<(usize, f64)> = Vec::new();
        let mut routed = Vec::new();
        for (i, &(s, d, b)) in msgs.iter().enumerate() {
            let (pa, pb) = (self.placements[s], self.placements[d]);
            if pa.node == pb.node {
                intra.push((i, self.intra_node_time(&pa, &pb, b)));
            } else {
                let f = self.flow(s, d, b);
                let path = self.router.route(&f);
                self.counters.record_send_class(self.nics[s], b, f.class);
                routed.push(RoutedFlow { flow: f, path });
                fabric_idx.push(i);
            }
        }
        let start = msgs
            .iter()
            .flat_map(|&(s, d, _)| [self.clock[s], self.clock[d]])
            .fold(0.0, f64::max);
        let mut per_msg = vec![0.0f64; msgs.len()];
        for (i, t) in &intra {
            per_msg[*i] = *t;
        }
        if !routed.is_empty() {
            let times = if routed.len() <= self.des_flow_limit {
                DesSim::new(self.topo, self.des_opts.clone())
                    .session(&mut self.scratch)
                    .simultaneous(&routed)
            } else {
                self.cost_model().eval_round(&routed)
            };
            for (k, &i) in fabric_idx.iter().enumerate() {
                per_msg[i] = times.per_flow[k];
            }
        }
        let mut round = 0.0f64;
        for (i, &(s, d, _)) in msgs.iter().enumerate() {
            let t = start + per_msg[i];
            self.clock[s] = self.clock[s].max(t);
            self.clock[d] = self.clock[d].max(t);
            round = round.max(per_msg[i]);
        }
        // ordered-delivery bookkeeping: destinations now idle
        for &(s, d, _) in msgs {
            self.router.destination_idle(self.nics[s], self.nics[d]);
        }
        round
    }

    /// Point-to-point latency with `window` outstanding messages (the
    /// ALCF benchmark of Fig 10 uses a 16-message window): reported value
    /// is the average per-message latency.
    pub fn p2p_latency(&mut self, src: usize, dst: usize, bytes: u64,
                       window: usize) -> f64 {
        let flow = self.flow(src, dst, bytes);
        let path = self.router.route(&flow);
        let cm = self.cost_model();
        let lat = cm.msg_latency(&path, bytes, self.buf);
        let ser = bytes as f64
            / cm.nic_eff_bw(self.buf).min(cm.rank_issue_bw(self.buf));
        // window messages pipeline over the wire: the first pays full
        // latency, the rest are serialization-gated
        let total =
            lat + window as f64 * ser.max(1.0 / self.topo.cfg.nic_msg_rate);
        self.counters.record_send_class(
            self.nics[src],
            bytes * window as u64,
            flow.class,
        );
        lat.max(total / window as f64)
    }

    /// Inject network timeouts (fabric events / node issues — §3.8.6).
    pub fn inject_timeouts(&mut self, n: u64) {
        self.counters.timeouts += n;
    }

    /// The MPICH summary line printed after a job (§3.8.6).
    pub fn mpich_summary(&self) -> String {
        format!(
            "MPICH Slingshot Network Summary: {} network timeouts.",
            self.counters.timeouts
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::machine::Machine;

    fn world(m: &Machine, nodes: usize, ppn: usize) -> World<'_> {
        World::new(&m.topo, m.place_job(0, nodes, ppn))
    }

    #[test]
    fn comm_split() {
        let c = Comm::world(12);
        let subs = c.split(|i| i / 4);
        assert_eq!(subs.len(), 3);
        assert_eq!(subs[0].ranks, vec![0, 1, 2, 3]);
    }

    #[test]
    fn open_loop_service_is_deterministic_and_advances_clocks() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mix = vec![
            RpcClass { bytes: 4 << 10, weight: 0.8 },
            RpcClass { bytes: 64 << 10, weight: 0.2 },
        ];
        let run = || {
            let mut w = world(&m, 4, 2);
            let ss = w.open_loop_service(
                7, 20_000.0, 1_000, mix.clone(), 1e-3, 5e-3,
            );
            (ss, w.elapsed())
        };
        let (a, ta) = run();
        let (b, tb) = run();
        assert_eq!(a, b, "service tier must be deterministic");
        assert_eq!(ta.to_bits(), tb.to_bits());
        assert_eq!(a.arrivals, 1_000);
        assert_eq!(a.completed, 1_000);
        assert!(a.p50 > 0.0 && a.p50 <= a.p99 && a.p99 <= a.p999);
        assert!(a.throughput_flows > 0.0);
        assert!(ta >= a.duration, "clocks advance past the service span");
    }

    #[test]
    fn exchange_advances_clocks() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 4, 2);
        let d = w.exchange(&[(0, 2, 4096), (4, 6, 4096)]);
        assert!(d > 0.0);
        assert!(w.clock[0] > 0.0 && w.clock[6] > 0.0);
        assert_eq!(w.clock[1], 0.0, "uninvolved rank unaffected");
    }

    #[test]
    fn intra_node_faster_than_inter_node() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 4, 2);
        let bytes = 1 << 20;
        let intra = w.solo_msg_time(0, 1, bytes); // same node, 2 ranks/node
        let inter = w.solo_msg_time(0, 7, bytes); // different nodes
        assert!(intra < inter, "intra {intra} inter {inter}");
    }

    #[test]
    fn p2p_latency_shape_matches_fig10() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 8, 1);
        let l8 = w.p2p_latency(0, 7, 8, 16);
        let l64 = w.p2p_latency(0, 7, 64, 16);
        let l128 = w.p2p_latency(0, 7, 128, 16);
        let l1m = w.p2p_latency(0, 7, 1 << 20, 16);
        assert!((l8 - l64).abs() < 0.15e-6, "flat small-msg region");
        assert!(l128 > l64, "SRAM->DRAM step");
        assert!(l1m > 20.0 * l128, "bandwidth regime");
    }

    #[test]
    fn counters_accumulate() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 2, 1);
        w.exchange(&[(0, 1, 1000)]);
        assert!(w.counters.total_bytes() >= 1000);
        w.inject_timeouts(28);
        assert_eq!(
            w.mpich_summary(),
            "MPICH Slingshot Network Summary: 28 network timeouts."
        );
    }

    #[test]
    fn sync_clocks_levels_ranks() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 2, 2);
        w.compute(0, 5.0);
        w.sync_clocks(&Comm::world(4), 0.0);
        assert!(w.clock.iter().all(|&c| c == 5.0));
    }

    #[test]
    fn des_exchange_prices_one_round_closed_loop() {
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut w = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        let d = w.exchange(&[(0, 4, 1 << 20), (1, 5, 1 << 20)]);
        assert!(d > 0.0);
        assert!(w.clock[0] > 0.0 && w.clock[5] > 0.0);
        assert_eq!(w.clock[2], 0.0, "uninvolved rank unaffected");
    }

    #[test]
    fn superstep_chains_exchange_rounds() {
        // the same two rounds: staged as one dependency-released
        // superstep, round 2 must wait for round 1 per rank — so the
        // chained elapsed time clearly exceeds one round alone
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let round1 = [(0usize, 4usize, 8u64 << 20)];
        let round2 = [(4usize, 0usize, 8u64 << 20)];
        let mut w1 = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        w1.exchange(&round1);
        let one = w1.elapsed();
        let mut w = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        w.begin_superstep();
        assert!(w.staging());
        assert_eq!(w.exchange(&round1), 0.0, "staged rounds defer pricing");
        w.exchange(&round2);
        let span = w.end_superstep();
        assert!(!w.staging());
        assert!(span > one * 1.5, "span {span} vs one round {one}");
        assert!((w.elapsed() - span).abs() < 1e-12);
    }

    #[test]
    fn exchange_now_prices_during_staging() {
        // duration-consuming callers (RMA wire rounds, OSU probes) must
        // get a real value even while supersteps are being staged
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut w = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        w.begin_superstep();
        assert_eq!(w.exchange(&[(0, 4, 1 << 20)]), 0.0);
        let t = w.exchange_now(&[(1, 5, 1 << 20)]);
        assert!(t > 0.0, "exchange_now must price immediately: {t}");
        assert!(w.staging(), "staging state unaffected");
        w.end_superstep();
    }

    #[test]
    fn des_exchange_duration_excludes_prior_clock_skew() {
        // regression: the Des-tier round duration is measured from the
        // latest participant start (analytic contract), not from the
        // earliest floor — pre-existing skew must not inflate it
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut w = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        w.compute(0, 10.0); // rank 0 busy until t=10
        let d = w.exchange(&[(0, 4, 1 << 20), (1, 5, 1 << 20)]);
        assert!(d > 0.0 && d < 1.0, "round duration {d} inflated by skew");
        assert!(w.clock[4] > 10.0, "rank 0's flow still floored at t=10");
    }

    #[test]
    fn superstep_compute_serializes_between_staged_rounds() {
        // regression: a compute phase between two staged exchanges must
        // sit ON the priced dependency chain (plain World::compute only
        // moves the wall-clock floor, which staged rounds already past
        // it would overlap)
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let run = |compute: f64| {
            let mut w =
                World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
            w.begin_superstep();
            w.exchange(&[(0, 4, 1 << 20)]);
            if compute > 0.0 {
                w.superstep_compute(4, compute);
            }
            w.exchange(&[(4, 0, 1 << 20)]);
            w.end_superstep()
        };
        let without = run(0.0);
        let with = run(0.5);
        assert!(
            (with - (without + 0.5)).abs() < 1e-9,
            "compute must separate the rounds: {without} vs {with}"
        );
    }

    #[test]
    fn superstep_floors_respect_rank_clocks() {
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut w = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        w.begin_superstep();
        w.compute(0, 1.0); // rank 0 busy until t=1
        w.exchange(&[(0, 4, 1 << 20)]);
        w.end_superstep();
        assert!(w.clock[4] > 1.0, "transfer cannot start before its floor");
    }

    #[test]
    fn superstep_is_noop_on_analytic_tier() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 4, 2);
        w.begin_superstep();
        assert!(!w.staging(), "analytic tier never stages");
        let d = w.exchange(&[(0, 2, 4096)]);
        assert!(d > 0.0, "analytic exchange still prices immediately");
        assert_eq!(w.end_superstep(), 0.0);
    }
}
