//! The MPI runtime over the simulated fabric (paper §4.4-§4.5).
//!
//! Mirrors the Aurora software stack: MPICH CH4 -> libfabric CXI provider
//! -> Cassini NIC. A [`World`] holds rank placements, per-rank clocks and
//! the adaptive router; [`coll`] implements the collective algorithms
//! whose switch-over Fig 14 shows; [`rma`] implements the one-sided model
//! of §5.3.5 (software-emulated GPU RMA, HMEM path, fence-or-overflow);
//! [`counters`] is the CXI counter reporting of §3.8.8.
//!
//! Two usage modes share every code path:
//! * **timing**: ops advance per-rank clocks using the fabric cost tiers;
//! * **functional**: `*_data` variants also move/reduce real `f64`
//!   payloads so end-to-end numerics can be validated.

pub mod coll;
pub mod counters;
pub mod rma;

use crate::config::AuroraConfig;
use crate::fabric::des::{DesOpts, DesSim};
use crate::fabric::rounds::CostModel;
use crate::fabric::workload::DagBuilder;
use crate::fabric::{BufLoc, Flow, Router, RoutedFlow, TrafficClass};
use crate::node::{NodePaths, RankLoc};
use crate::topology::Topology;
use counters::CxiCounters;

/// Which fabric tier prices collective rounds (see [`coll`]).
///
/// * `Analytic` (default): each round is costed independently by the
///   round/DES tier and rounds are summed — fast, but blind to
///   cross-round queueing dynamics.
/// * `Des`: collectives emit a dependency DAG of rounds executed
///   closed-loop on the DES ([`crate::fabric::DesSim::run_dag`]): a
///   round's completion releases the next round's flows, so congestion
///   and back-pressure propagate between rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricTier {
    Analytic,
    Des,
}

/// A communicator: an ordered set of world ranks.
#[derive(Debug, Clone)]
pub struct Comm {
    pub ranks: Vec<usize>,
}

impl Comm {
    pub fn world(n: usize) -> Self {
        Self { ranks: (0..n).collect() }
    }

    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// MPI_Comm_split by color; key = current order.
    pub fn split(&self, color: impl Fn(usize) -> usize) -> Vec<Comm> {
        let mut groups: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for (i, &r) in self.ranks.iter().enumerate() {
            groups.entry(color(i)).or_default().push(r);
        }
        groups.into_values().map(|ranks| Comm { ranks }).collect()
    }
}

/// Superstep staging state (`FabricTier::Des`): exchanges accumulate as
/// dependency-released rounds keyed by world rank and are priced as one
/// closed-loop DAG at the next flush point (a collective, or an explicit
/// [`World::flush_steps`] / [`World::end_superstep`]).
#[derive(Default)]
struct StagedSteps {
    builder: DagBuilder,
    /// Per staged node: participating world ranks and, for fabric
    /// transfers, the NIC pair for router idle bookkeeping.
    nodes: Vec<(usize, usize, Option<(u32, u32)>)>,
}

/// The simulated MPI world.
pub struct World<'t> {
    pub topo: &'t Topology,
    pub router: Router<'t>,
    pub placements: Vec<RankLoc>,
    /// Global NIC id per rank.
    pub nics: Vec<u32>,
    /// Per-rank local clock (seconds).
    pub clock: Vec<f64>,
    pub counters: CxiCounters,
    /// Default buffer location for transfers (host or GPU-direct).
    pub buf: BufLoc,
    pub class: TrafficClass,
    /// Use the DES tier for rounds at or below this many flows; the
    /// round-based tier above (cross-validated in rust/tests).
    pub des_flow_limit: usize,
    /// How collectives are priced: analytic per-round (fast tier) or
    /// closed-loop dependency DAGs on the DES.
    pub tier: FabricTier,
    node_paths: NodePaths,
    des_opts: DesOpts,
    /// `Some` while exchange supersteps are being staged (Des tier).
    staged: Option<StagedSteps>,
}

impl<'t> World<'t> {
    pub fn new(topo: &'t Topology, placements: Vec<RankLoc>) -> Self {
        let nics = placements
            .iter()
            .map(|l| topo.nic_of_node(l.node, l.nic_idx))
            .collect();
        let n = placements.len();
        Self {
            topo,
            router: Router::new(topo),
            nics,
            clock: vec![0.0; n],
            counters: CxiCounters::new(),
            buf: BufLoc::Host,
            class: TrafficClass::BestEffort,
            des_flow_limit: 512,
            tier: FabricTier::Analytic,
            node_paths: NodePaths::new(&topo.cfg),
            des_opts: DesOpts::default(),
            staged: None,
            placements,
        }
    }

    pub fn gpu_buffers(mut self) -> Self {
        self.buf = BufLoc::Gpu;
        self
    }

    /// Switch collectives onto the closed-loop DES tier.
    pub fn des_fabric(mut self) -> Self {
        self.tier = FabricTier::Des;
        self
    }

    pub fn size(&self) -> usize {
        self.placements.len()
    }

    pub fn cfg(&self) -> &AuroraConfig {
        &self.topo.cfg
    }

    pub fn cost_model(&self) -> CostModel<'t> {
        CostModel::new(self.topo)
    }

    /// Max clock across ranks — the job's elapsed time.
    pub fn elapsed(&self) -> f64 {
        self.clock.iter().cloned().fold(0.0, f64::max)
    }

    /// Advance every rank in `comm` to the same time (a synchronizing op).
    pub fn sync_clocks(&mut self, comm: &Comm, extra: f64) {
        let t = comm
            .ranks
            .iter()
            .map(|&r| self.clock[r])
            .fold(0.0, f64::max)
            + extra;
        for &r in &comm.ranks {
            self.clock[r] = t;
        }
    }

    /// Per-rank compute: advances that rank's clock only.
    pub fn compute(&mut self, rank: usize, seconds: f64) {
        self.clock[rank] += seconds;
    }

    /// Cost of one message between two ranks, ignoring cross-flow
    /// contention (used for tree collectives where rounds serialize).
    pub fn solo_msg_time(&mut self, src: usize, dst: usize, bytes: u64) -> f64 {
        let (a, b) = (self.placements[src], self.placements[dst]);
        if a.node == b.node {
            return self.intra_node_time(&a, &b, bytes);
        }
        let flow = self.flow(src, dst, bytes);
        let path = self.router.route(&flow);
        self.counters.record_send_class(self.nics[src], bytes, flow.class);
        self.cost_model().solo_msg_time(&path, bytes, self.buf)
    }

    fn intra_node_time(&self, a: &RankLoc, b: &RankLoc, bytes: u64) -> f64 {
        let cfg = &self.topo.cfg;
        let bw = self
            .node_paths
            .intra_node_bw(a, b, matches!(self.buf, BufLoc::Gpu));
        // IPC-handle / shared-memory path: software overhead, no NIC
        0.4e-6 + cfg.mpi_overhead + bytes as f64 / bw
    }

    fn flow(&self, src: usize, dst: usize, bytes: u64) -> Flow {
        Flow {
            src_nic: self.nics[src],
            dst_nic: self.nics[dst],
            bytes,
            class: self.class,
            buf: self.buf,
            ordered: true, // MPI envelope ordering (§3.1)
        }
    }

    /// Whether exchange supersteps are currently being staged.
    pub fn staging(&self) -> bool {
        self.staged.is_some()
    }

    /// Begin dependency-released superstep staging (Des tier only; a
    /// no-op on the analytic tier). Subsequent [`World::exchange`]
    /// rounds accumulate into one closed-loop DAG — round k+1 released
    /// per rank by round k — instead of being priced independently.
    /// Collectives are flush points (their rounds join the staged DAG
    /// and the whole superstep prices as one dependency-released run);
    /// [`World::flush_steps`] flushes explicitly and
    /// [`World::end_superstep`] flushes and stops staging.
    pub fn begin_superstep(&mut self) {
        if matches!(self.tier, FabricTier::Des) && self.staged.is_none() {
            self.staged = Some(StagedSteps::default());
        }
    }

    /// Flush staged supersteps: price the accumulated DAG closed-loop,
    /// advance participant clocks to their node finishes, and keep
    /// staging active. Returns the flushed span (0 if nothing staged).
    pub fn flush_steps(&mut self) -> f64 {
        if self.staged.is_none() {
            return 0.0;
        }
        let t = self.end_superstep();
        self.staged = Some(StagedSteps::default());
        t
    }

    /// Flush staged supersteps and stop staging. Returns the wall span
    /// of the staged work (earliest release floor to last finish).
    pub fn end_superstep(&mut self) -> f64 {
        match self.staged.take() {
            Some(st) => {
                let (mk, min_floor, _) = self.execute_staged(st);
                mk - min_floor
            }
            None => 0.0,
        }
    }

    /// Per-rank compute inside a superstep: stages a compute node
    /// serialized after everything the rank has staged so far and gating
    /// the rank's next staged message — so compute genuinely separates
    /// staged communication phases in the priced DAG. Without an active
    /// superstep it is plain [`World::compute`] (immediate clock
    /// advance). Plain `compute` during staging only moves the wall
    /// clock (a release *floor*), which staged rounds already past that
    /// floor would overlap.
    pub fn superstep_compute(&mut self, rank: usize, seconds: f64) {
        if let Some(mut st) = self.staged.take() {
            let id = st.builder.compute(rank as u32, seconds.max(0.0));
            st.builder.set_floor(id, self.clock[rank]);
            st.nodes.push((rank, rank, None));
            self.staged = Some(st);
        } else {
            self.compute(rank, seconds);
        }
    }

    /// Stage one round of triples into `st`: intra-node messages become
    /// fixed-duration nodes, fabric messages are routed now; every node
    /// gets a release floor at its participants' current clocks (a rank
    /// cannot take part before its local time). `ordered` selects the
    /// flow's delivery mode: exchange rounds keep MPI envelope ordering
    /// (`true`, pinned routes — the pre-existing `exchange` semantics),
    /// while collective rounds staged at a flush point use `false` so
    /// they route exactly like the streamed / `rounds_dag` Des paths.
    fn stage_round_inner(
        &mut self,
        st: &mut StagedSteps,
        msgs: &[(usize, usize, u64)],
        ordered: bool,
    ) {
        for &(s, d, b) in msgs {
            let (pa, pb) = (self.placements[s], self.placements[d]);
            let floor = self.clock[s].max(self.clock[d]);
            let (id, nics) = if pa.node == pb.node {
                let dt = self.intra_node_time(&pa, &pb, b);
                (st.builder.compute_staged(s as u32, d as u32, dt), None)
            } else {
                let mut f = self.flow(s, d, b);
                f.ordered = ordered;
                let path = self.router.route(&f);
                self.counters.record_send_class(self.nics[s], b, f.class);
                let id = st
                    .builder
                    .xfer(s as u32, d as u32, RoutedFlow { flow: f, path });
                // destination-idle bookkeeping clears pinned routes, so
                // it only applies to ordered (route-pinned) exchange
                // flows — unordered collective rounds never pin and must
                // not unpin unrelated ordered traffic
                let idle = if ordered {
                    Some((self.nics[s], self.nics[d]))
                } else {
                    None
                };
                (id, idle)
            };
            st.builder.set_floor(id, floor);
            st.nodes.push((s, d, nics));
        }
        st.builder.end_round();
    }

    /// Execute a staged DAG closed-loop and advance clocks. Returns
    /// `(makespan, min_floor, max_floor)` — absolute last finish plus
    /// the earliest and latest release floors, so callers can report
    /// either the wall span of the whole superstep (`makespan -
    /// min_floor`) or, for a single round, the duration from the latest
    /// participant start (`makespan - max_floor`, the analytic-tier
    /// contract).
    fn execute_staged(&mut self, st: StagedSteps) -> (f64, f64, f64) {
        let dag = st.builder.finish();
        if dag.is_empty() {
            return (0.0, 0.0, 0.0);
        }
        let (min_floor, max_floor) = dag.nodes.iter().fold(
            (f64::INFINITY, 0.0f64),
            |(lo, hi), n| (lo.min(n.start), hi.max(n.start)),
        );
        let res =
            DesSim::new(self.topo, self.des_opts.clone()).run_dag(&dag);
        for (i, &(s, d, nics)) in st.nodes.iter().enumerate() {
            let t = res.node_finish[i];
            self.clock[s] = self.clock[s].max(t);
            self.clock[d] = self.clock[d].max(t);
            if let Some((sn, dn)) = nics {
                self.router.destination_idle(sn, dn);
            }
        }
        (res.makespan, min_floor.min(res.makespan), max_floor)
    }

    /// Stage round triples after any pending exchanges and flush: the
    /// whole superstep — pending exchange rounds plus these rounds —
    /// prices as one dependency-released DAG (collective flush points).
    /// Staging stays active for the next superstep. Requires staging.
    pub(crate) fn stage_rounds_and_flush(
        &mut self,
        rounds: &[Vec<(usize, usize, u64)>],
    ) -> f64 {
        let mut st = self.staged.take().expect("superstep staging active");
        for round in rounds {
            self.stage_round_inner(&mut st, round, false);
        }
        let (mk, min_floor, _) = self.execute_staged(st);
        self.staged = Some(StagedSteps::default());
        mk - min_floor
    }

    /// Execute one communication round: `(src, dst, bytes)` triples that
    /// start together. Advances the clocks of all participants; returns
    /// the round's duration (from the latest participant start).
    ///
    /// On `FabricTier::Des` the round runs **closed-loop**: while
    /// superstep staging is active ([`World::begin_superstep`]) it is
    /// staged — released per rank by the previous round, priced at the
    /// next flush point, return value 0.0 until then — and otherwise it
    /// executes immediately as a one-round dependency DAG with per-rank
    /// clock floors. The analytic tier keeps the original independent
    /// round pricing.
    pub fn exchange(&mut self, msgs: &[(usize, usize, u64)]) -> f64 {
        if msgs.is_empty() {
            return 0.0;
        }
        if matches!(self.tier, FabricTier::Des) {
            if let Some(mut st) = self.staged.take() {
                self.stage_round_inner(&mut st, msgs, true);
                self.staged = Some(st);
                return 0.0; // priced at the next flush point
            }
        }
        self.exchange_now(msgs)
    }

    /// Execute one round and price it **immediately**, regardless of
    /// superstep staging — for callers that consume the returned
    /// duration (the RMA wire round, the OSU bandwidth probes, anything
    /// dividing bytes by the result). Pending staged rounds are left
    /// pending and unpriced; call [`World::flush_steps`] first if this
    /// round must observe their clock effects.
    pub fn exchange_now(&mut self, msgs: &[(usize, usize, u64)]) -> f64 {
        if msgs.is_empty() {
            return 0.0;
        }
        match self.tier {
            FabricTier::Des => {
                let mut st = StagedSteps::default();
                self.stage_round_inner(&mut st, msgs, true);
                // single round: duration from the latest participant
                // start (max floor), matching the analytic contract —
                // pre-existing clock skew is not part of the round time
                let (mk, _, max_floor) = self.execute_staged(st);
                (mk - max_floor).max(0.0)
            }
            FabricTier::Analytic => self.exchange_analytic(msgs),
        }
    }

    /// The analytic-tier round pricing (independent per-round DES or
    /// round-tier evaluation above `des_flow_limit`).
    fn exchange_analytic(&mut self, msgs: &[(usize, usize, u64)]) -> f64 {
        // split intra-node messages (no fabric) from fabric flows
        let mut fabric_idx = Vec::new();
        let mut intra: Vec<(usize, f64)> = Vec::new();
        let mut routed = Vec::new();
        for (i, &(s, d, b)) in msgs.iter().enumerate() {
            let (pa, pb) = (self.placements[s], self.placements[d]);
            if pa.node == pb.node {
                intra.push((i, self.intra_node_time(&pa, &pb, b)));
            } else {
                let f = self.flow(s, d, b);
                let path = self.router.route(&f);
                self.counters.record_send_class(self.nics[s], b, f.class);
                routed.push(RoutedFlow { flow: f, path });
                fabric_idx.push(i);
            }
        }
        let start = msgs
            .iter()
            .flat_map(|&(s, d, _)| [self.clock[s], self.clock[d]])
            .fold(0.0, f64::max);
        let mut per_msg = vec![0.0f64; msgs.len()];
        for (i, t) in &intra {
            per_msg[*i] = *t;
        }
        if !routed.is_empty() {
            let times = if routed.len() <= self.des_flow_limit {
                DesSim::new(self.topo, self.des_opts.clone())
                    .run_simultaneous(&routed)
            } else {
                self.cost_model().eval_round(&routed)
            };
            for (k, &i) in fabric_idx.iter().enumerate() {
                per_msg[i] = times.per_flow[k];
            }
        }
        let mut round = 0.0f64;
        for (i, &(s, d, _)) in msgs.iter().enumerate() {
            let t = start + per_msg[i];
            self.clock[s] = self.clock[s].max(t);
            self.clock[d] = self.clock[d].max(t);
            round = round.max(per_msg[i]);
        }
        // ordered-delivery bookkeeping: destinations now idle
        for &(s, d, _) in msgs {
            self.router.destination_idle(self.nics[s], self.nics[d]);
        }
        round
    }

    /// Point-to-point latency with `window` outstanding messages (the
    /// ALCF benchmark of Fig 10 uses a 16-message window): reported value
    /// is the average per-message latency.
    pub fn p2p_latency(&mut self, src: usize, dst: usize, bytes: u64,
                       window: usize) -> f64 {
        let flow = self.flow(src, dst, bytes);
        let path = self.router.route(&flow);
        let cm = self.cost_model();
        let lat = cm.msg_latency(&path, bytes, self.buf);
        let ser = bytes as f64
            / cm.nic_eff_bw(self.buf).min(cm.rank_issue_bw(self.buf));
        // window messages pipeline over the wire: the first pays full
        // latency, the rest are serialization-gated
        let total =
            lat + window as f64 * ser.max(1.0 / self.topo.cfg.nic_msg_rate);
        self.counters.record_send_class(
            self.nics[src],
            bytes * window as u64,
            flow.class,
        );
        lat.max(total / window as f64)
    }

    /// Inject network timeouts (fabric events / node issues — §3.8.6).
    pub fn inject_timeouts(&mut self, n: u64) {
        self.counters.timeouts += n;
    }

    /// The MPICH summary line printed after a job (§3.8.6).
    pub fn mpich_summary(&self) -> String {
        format!(
            "MPICH Slingshot Network Summary: {} network timeouts.",
            self.counters.timeouts
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AuroraConfig;
    use crate::machine::Machine;

    fn world(m: &Machine, nodes: usize, ppn: usize) -> World<'_> {
        World::new(&m.topo, m.place_job(0, nodes, ppn))
    }

    #[test]
    fn comm_split() {
        let c = Comm::world(12);
        let subs = c.split(|i| i / 4);
        assert_eq!(subs.len(), 3);
        assert_eq!(subs[0].ranks, vec![0, 1, 2, 3]);
    }

    #[test]
    fn exchange_advances_clocks() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 4, 2);
        let d = w.exchange(&[(0, 2, 4096), (4, 6, 4096)]);
        assert!(d > 0.0);
        assert!(w.clock[0] > 0.0 && w.clock[6] > 0.0);
        assert_eq!(w.clock[1], 0.0, "uninvolved rank unaffected");
    }

    #[test]
    fn intra_node_faster_than_inter_node() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 4, 2);
        let bytes = 1 << 20;
        let intra = w.solo_msg_time(0, 1, bytes); // same node, 2 ranks/node
        let inter = w.solo_msg_time(0, 7, bytes); // different nodes
        assert!(intra < inter, "intra {intra} inter {inter}");
    }

    #[test]
    fn p2p_latency_shape_matches_fig10() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 8, 1);
        let l8 = w.p2p_latency(0, 7, 8, 16);
        let l64 = w.p2p_latency(0, 7, 64, 16);
        let l128 = w.p2p_latency(0, 7, 128, 16);
        let l1m = w.p2p_latency(0, 7, 1 << 20, 16);
        assert!((l8 - l64).abs() < 0.15e-6, "flat small-msg region");
        assert!(l128 > l64, "SRAM->DRAM step");
        assert!(l1m > 20.0 * l128, "bandwidth regime");
    }

    #[test]
    fn counters_accumulate() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 2, 1);
        w.exchange(&[(0, 1, 1000)]);
        assert!(w.counters.total_bytes() >= 1000);
        w.inject_timeouts(28);
        assert_eq!(
            w.mpich_summary(),
            "MPICH Slingshot Network Summary: 28 network timeouts."
        );
    }

    #[test]
    fn sync_clocks_levels_ranks() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 2, 2);
        w.compute(0, 5.0);
        w.sync_clocks(&Comm::world(4), 0.0);
        assert!(w.clock.iter().all(|&c| c == 5.0));
    }

    #[test]
    fn des_exchange_prices_one_round_closed_loop() {
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut w = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        let d = w.exchange(&[(0, 4, 1 << 20), (1, 5, 1 << 20)]);
        assert!(d > 0.0);
        assert!(w.clock[0] > 0.0 && w.clock[5] > 0.0);
        assert_eq!(w.clock[2], 0.0, "uninvolved rank unaffected");
    }

    #[test]
    fn superstep_chains_exchange_rounds() {
        // the same two rounds: staged as one dependency-released
        // superstep, round 2 must wait for round 1 per rank — so the
        // chained elapsed time clearly exceeds one round alone
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let round1 = [(0usize, 4usize, 8u64 << 20)];
        let round2 = [(4usize, 0usize, 8u64 << 20)];
        let mut w1 = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        w1.exchange(&round1);
        let one = w1.elapsed();
        let mut w = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        w.begin_superstep();
        assert!(w.staging());
        assert_eq!(w.exchange(&round1), 0.0, "staged rounds defer pricing");
        w.exchange(&round2);
        let span = w.end_superstep();
        assert!(!w.staging());
        assert!(span > one * 1.5, "span {span} vs one round {one}");
        assert!((w.elapsed() - span).abs() < 1e-12);
    }

    #[test]
    fn exchange_now_prices_during_staging() {
        // duration-consuming callers (RMA wire rounds, OSU probes) must
        // get a real value even while supersteps are being staged
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut w = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        w.begin_superstep();
        assert_eq!(w.exchange(&[(0, 4, 1 << 20)]), 0.0);
        let t = w.exchange_now(&[(1, 5, 1 << 20)]);
        assert!(t > 0.0, "exchange_now must price immediately: {t}");
        assert!(w.staging(), "staging state unaffected");
        w.end_superstep();
    }

    #[test]
    fn des_exchange_duration_excludes_prior_clock_skew() {
        // regression: the Des-tier round duration is measured from the
        // latest participant start (analytic contract), not from the
        // earliest floor — pre-existing skew must not inflate it
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut w = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        w.compute(0, 10.0); // rank 0 busy until t=10
        let d = w.exchange(&[(0, 4, 1 << 20), (1, 5, 1 << 20)]);
        assert!(d > 0.0 && d < 1.0, "round duration {d} inflated by skew");
        assert!(w.clock[4] > 10.0, "rank 0's flow still floored at t=10");
    }

    #[test]
    fn superstep_compute_serializes_between_staged_rounds() {
        // regression: a compute phase between two staged exchanges must
        // sit ON the priced dependency chain (plain World::compute only
        // moves the wall-clock floor, which staged rounds already past
        // it would overlap)
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let run = |compute: f64| {
            let mut w =
                World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
            w.begin_superstep();
            w.exchange(&[(0, 4, 1 << 20)]);
            if compute > 0.0 {
                w.superstep_compute(4, compute);
            }
            w.exchange(&[(4, 0, 1 << 20)]);
            w.end_superstep()
        };
        let without = run(0.0);
        let with = run(0.5);
        assert!(
            (with - (without + 0.5)).abs() < 1e-9,
            "compute must separate the rounds: {without} vs {with}"
        );
    }

    #[test]
    fn superstep_floors_respect_rank_clocks() {
        let m = Machine::new(&AuroraConfig::small(4, 4));
        let mut w = World::new(&m.topo, m.place_job(0, 8, 1)).des_fabric();
        w.begin_superstep();
        w.compute(0, 1.0); // rank 0 busy until t=1
        w.exchange(&[(0, 4, 1 << 20)]);
        w.end_superstep();
        assert!(w.clock[4] > 1.0, "transfer cannot start before its floor");
    }

    #[test]
    fn superstep_is_noop_on_analytic_tier() {
        let m = Machine::new(&AuroraConfig::tiny());
        let mut w = world(&m, 4, 2);
        w.begin_superstep();
        assert!(!w.staging(), "analytic tier never stages");
        let d = w.exchange(&[(0, 2, 4096)]);
        assert!(d > 0.0, "analytic exchange still prices immediately");
        assert_eq!(w.end_superstep(), 0.0);
    }
}
